"""Paper Table II: latency / throughput / cost / latency-std for every
registered policy, evaluated through the vmapped sweep grid, with allocator
call timing (the paper's <1 ms O(N) claim)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks import _smoke
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.allocator import adaptive_allocation
from repro.core.sweep import Scenario, sweep

PAPER_TABLE2 = {
    "static_equal": {"avg_latency": 110.3, "total_throughput": 60.0, "cost": 0.020},
    "round_robin": {"avg_latency": 756.1, "total_throughput": 60.0, "cost": 0.020},
    "adaptive": {"avg_latency": 111.9, "total_throughput": 58.1, "cost": 0.020},
}


def run(out_dir: str | None = None) -> list[str]:
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    fleet = paper_fleet()
    scen = Scenario("constant", workload.constant(jnp.asarray(PAPER_ARRIVAL_RATES), 100))
    res = sweep(fleet, (scen,))
    rows = {}
    for policy in res.policy_names:
        s = res.summary(policy, "constant")
        rows[policy] = {
            "avg_latency": round(s.avg_latency, 1),
            "latency_std": round(s.latency_std, 2),
            "total_throughput": round(s.total_throughput, 2),
            "cost": round(s.cost, 3),
            "per_agent_latency": [round(x, 1) for x in s.per_agent_latency],
            "per_agent_throughput": [round(x, 2) for x in s.per_agent_throughput],
        }
        if policy in PAPER_TABLE2:
            rows[policy]["paper"] = PAPER_TABLE2[policy]

    # Allocator wall time (jitted, after warmup) — paper claims <1 ms.
    lam = jnp.asarray(PAPER_ARRIVAL_RATES, jnp.float32)
    f = jax.jit(lambda l: adaptive_allocation(l, fleet.min_gpu, fleet.priority))
    f(lam).block_until_ready()
    t0 = time.perf_counter()
    n = _smoke.reps(1000, 20)
    for _ in range(n):
        f(lam).block_until_ready()
    us = (time.perf_counter() - t0) / n * 1e6

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "table2.json"), "w") as fh:
        json.dump({"rows": rows, "allocator_us": us}, fh, indent=1)

    out = [f"table2/alloc_call,{us:.1f},adaptive_lat={rows['adaptive']['avg_latency']}"]
    for p, r in rows.items():
        out.append(
            f"table2/{p},0,lat={r['avg_latency']};tput={r['total_throughput']};cost={r['cost']}"
        )
    return out
