"""System-level O(N) claim: full jitted sweep wall time per simulated step
vs fleet size — streaming kernel vs the trace-based oracle.

The paper argues Algorithm 1 is O(N); ``allocator_scaling`` times the bare
allocator.  This benchmark times the *whole evaluation surface* — the jitted
(policy × scenario) sweep over the simulator, i.e. allocator + queue
dynamics + metric reductions — per simulated step at N ∈ {4, 8, 16, 64,
256} agents, for BOTH grid kernels: the streaming default (O(P) policy
dispatch, metrics accumulated in the scan carry) and the trace-materializing
oracle (vmapped ``lax.switch``, P² policy evaluations per grid).  It also
times the single batched (fleet × policy × scenario) grid that covers every
size at once through the padded/masked fleet axis, probes peak process
memory to show the streaming kernel's footprint does not grow with the
horizon, and — outside smoke mode — runs the N=1024, S=10⁴ frontier grid
that trace materialization made infeasible.

Timing blocks on the jitted device output (``jax.block_until_ready`` via
``return_arrays=True``) so wall times measure device work, not dispatch +
host transfer.

Writes ``experiments/paper/fleet_scaling.json`` and the stable-schema
``BENCH_fleet_scaling.json`` at the repo root (see ``benchmarks/_bench.py``)
so future PRs can track the speedup.
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks import _bench, _smoke
from repro.core import allocator as alloc
from repro.core import workload
from repro.core.agents import synthetic_fleet
from repro.core.sweep import (
    fleet_scenario_library,
    scenario_library,
    sweep,
    sweep_fleets,
)

FLEET_SIZES = (4, 8, 16, 64, 256)
NUM_STEPS = 50
SEED = 0
REPS = 20          # timing samples per per-fleet grid
BATCHED_REPS = 3   # the batched grid covers all sizes at once; it is slow
# The frontier grid: long-horizon fleet scale that only the streaming
# kernel can reach (trace mode would materialize ~18 GB of trajectories).
FRONTIER_N = 1024
FRONTIER_STEPS = 10_000
# Memory probe: the same grid at a 10x horizon; streaming peak memory must
# stay flat while trace materialization grows linearly.
MEMORY_PROBE_N = 256
MEMORY_HORIZONS = (50, 500)


def _measure_memory_flatness(entries: list) -> dict:
    """Peak-RSS growth with the horizon, per kernel.

    ``ru_maxrss`` is a monotone high-water mark, so modes run cheapest
    first: streaming at S then 10S (flat by construction — the carry is
    O(N)), then the trace kernel with ``keep_traces=True`` at 10S, whose
    (S, N)-leaf materialization is what raises the mark.
    """
    n_probe = 64 if _smoke.smoke() else MEMORY_PROBE_N
    fleet = synthetic_fleet(n_probe, seed=n_probe)
    rates = workload.synthetic_rates(n_probe, seed=n_probe)
    horizons = tuple(_smoke.steps(s) for s in MEMORY_HORIZONS)
    probe = {}
    cases = [
        ("streaming", horizons[0], {}),
        ("streaming", horizons[1], {}),
        ("trace_keep_traces", horizons[1], {"keep_traces": True, "stream": False}),
    ]
    for kernel, steps, kwargs in cases:
        scenarios = scenario_library(rates, num_steps=steps, seed=SEED)
        out = sweep(fleet, scenarios, return_arrays=True, **kwargs)
        jax.block_until_ready(out)
        live = _bench.live_bytes()
        rss = _bench.max_rss_bytes()
        del out
        probe[f"{kernel}_s{steps}"] = {"max_rss_bytes": rss, "live_bytes": live}
        entries.append({
            "grid": "memory_probe", "kernel": kernel, "n": n_probe,
            "num_steps": steps, "max_rss_bytes": rss, "live_bytes": live,
            "peak_device_bytes": _bench.peak_bytes(),
        })
    return probe


def run(out_dir: str | None = None) -> list[str]:
    bench_dir = out_dir  # explicit destination redirects BENCH files too
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    sizes = _smoke.sizes(FLEET_SIZES)
    num_steps = _smoke.steps(NUM_STEPS)
    reps = _smoke.reps(REPS, 2)
    per_fleet = {}
    entries: list[dict] = []
    # The memory probe runs FIRST: ru_maxrss is a process-wide monotone
    # high-water mark, so its per-case readings are only attributable while
    # no heavier grid has run yet.  (The timing entries below deliberately
    # carry no max_rss field for the same reason.)
    memory = _measure_memory_flatness(entries)
    fleets = [synthetic_fleet(n, seed=n) for n in sizes]
    num_policies = len(alloc.policy_names())
    for n, fleet in zip(sizes, fleets):
        rates = workload.synthetic_rates(n, seed=n)
        scenarios = scenario_library(rates, num_steps=num_steps, seed=SEED)
        cells = num_policies * len(scenarios)
        wall = {}
        for kernel, fn in (
            ("streaming",
             lambda: sweep(fleet, scenarios, return_arrays=True)),
            ("trace",
             lambda: sweep(fleet, scenarios, stream=False, return_arrays=True)),
        ):
            wall[kernel] = _bench.time_device(fn, reps)
            entries.append(_bench.timing_entry(
                "per_fleet", kernel, n, num_steps, cells, wall[kernel]
            ))
        per_fleet[n] = {
            "grid_us": wall["streaming"],
            "us_per_step": wall["streaming"] / num_steps,
            "us_per_step_per_cell": wall["streaming"] / (num_steps * cells),
            "cells": cells,
            "trace_grid_us": wall["trace"],
            "stream_speedup": wall["trace"] / wall["streaming"],
        }

    # The batched path: every fleet size in ONE padded (F, P, W) grid,
    # sharded across jax.devices().
    rate_vectors = [workload.synthetic_rates(n, seed=n) for n in sizes]
    batched_wall = {}
    for kernel, stream in (("streaming", True), ("trace", False)):
        batched_wall[kernel] = _bench.time_device(
            lambda: sweep_fleets(
                fleets, rate_vectors, num_steps=num_steps, seed=SEED,
                stream=stream, return_arrays=True,
            ),
            _smoke.reps(BATCHED_REPS, 1),
        )
    batched = {
        "grid_us": batched_wall["streaming"],
        "us_per_step": batched_wall["streaming"] / num_steps,
        "trace_grid_us": batched_wall["trace"],
        "stream_speedup": batched_wall["trace"] / batched_wall["streaming"],
        "fleets": len(sizes),
        "padded_width": max(sizes),
        # Count scenarios from the library sweep_fleets actually runs (a
        # 1-fleet build at the smallest size — names only, no grid work).
        "cells": len(sizes) * num_policies * len(
            fleet_scenario_library(rate_vectors[:1], fleets[0].num_agents,
                                   num_steps, SEED)[0]
        ),
    }
    for kernel in ("streaming", "trace"):
        entries.append(_bench.timing_entry(
            "batched", kernel, max(sizes), num_steps, batched["cells"],
            batched_wall[kernel],
        ))

    frontier = None
    if not _smoke.smoke():
        # Previously infeasible: N=1024 agents over a 10^4-step horizon —
        # trace mode would materialize 56 cells x 8 (S, N) leaves (~18 GB);
        # the streaming carry keeps the whole grid at O(P · W · N).
        # Feasibility runs through the full sweep_fleets entry point
        # (end-to-end wall clock, prep included); the kernel timing then
        # hoists fleet + scenario generation out of the timed region like
        # every per_fleet entry, so the rows stay comparable.
        frontier_fleet = synthetic_fleet(FRONTIER_N, seed=FRONTIER_N)
        t0 = time.perf_counter()
        out = sweep_fleets(
            [frontier_fleet], num_steps=FRONTIER_STEPS, seed=SEED,
            return_arrays=True,
        )
        jax.block_until_ready(out)
        entry_point_us = (time.perf_counter() - t0) * 1e6
        cells = int(out[0][..., 0].size)
        del out
        frontier_scenarios = scenario_library(
            workload.synthetic_rates(FRONTIER_N, seed=SEED),
            num_steps=FRONTIER_STEPS, seed=SEED,
        )
        wall_us = _bench.time_device(
            lambda: sweep(frontier_fleet, frontier_scenarios,
                          return_arrays=True),
            1,
        )
        frontier = {
            "n": FRONTIER_N, "num_steps": FRONTIER_STEPS,
            "grid_us": wall_us, "us_per_step": wall_us / FRONTIER_STEPS,
            "sweep_fleets_end_to_end_us": entry_point_us,
            "cells": cells,
        }
        entries.append(_bench.timing_entry(
            "frontier", "streaming", FRONTIER_N, FRONTIER_STEPS, cells,
            wall_us, max_rss_bytes=_bench.max_rss_bytes(),
            sweep_fleets_end_to_end_us=entry_point_us,
        ))

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fleet_scaling.json"), "w") as fh:
        json.dump(
            {
                "num_steps": num_steps,
                "per_fleet": per_fleet,
                "batched": batched,
                "memory_probe": memory,
                "frontier": frontier,
            },
            fh, indent=1,
        )
    _bench.write("fleet_scaling", entries, out_dir=bench_dir)

    lo, hi = min(sizes), max(sizes)
    growth = per_fleet[hi]["us_per_step"] / per_fleet[lo]["us_per_step"]
    out = [
        f"scaling/sweep_step_n{lo},{per_fleet[lo]['us_per_step']:.1f},cells={per_fleet[lo]['cells']}",
        f"scaling/sweep_step_n{hi},{per_fleet[hi]['us_per_step']:.1f},growth_{hi // lo}x_agents={growth:.1f}x",
        f"scaling/stream_speedup_n{hi},{per_fleet[hi]['stream_speedup']:.2f},trace_us={per_fleet[hi]['trace_grid_us']:.1f}",
        f"scaling/fleet_grid,{batched_wall['streaming']:.1f},fleets={len(sizes)};padded_n={hi};speedup={batched['stream_speedup']:.2f}x",
    ]
    if frontier is not None:
        out.append(
            f"scaling/frontier_n{FRONTIER_N},{frontier['us_per_step']:.1f},steps={FRONTIER_STEPS}"
        )
    return out
