"""System-level O(N) claim: full jitted sweep wall time per simulated step
vs fleet size.

The paper argues Algorithm 1 is O(N); ``allocator_scaling`` times the bare
allocator.  This benchmark times the *whole evaluation surface* — the jitted
(policy × scenario) sweep over ``simulate_core``, i.e. allocator + queue
dynamics + metric reductions — per simulated step at N ∈ {4, 8, 16, 64,
256} agents, plus the single batched (fleet × policy × scenario) grid that
covers every size at once through the padded/masked fleet axis.

Writes ``experiments/paper/fleet_scaling.json``.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks import _smoke
from repro.core import workload
from repro.core.agents import synthetic_fleet
from repro.core.sweep import scenario_library, sweep, sweep_fleets

FLEET_SIZES = (4, 8, 16, 64, 256)
NUM_STEPS = 50
SEED = 0
REPS = 20          # timing samples per per-fleet grid
BATCHED_REPS = 3   # the batched grid covers all sizes at once; it is slow


def _time(fn, reps: int) -> float:
    """Mean wall time (us) over ``reps`` calls, after a warmup/compile call."""
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run(out_dir: str | None = None) -> list[str]:
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    sizes = _smoke.sizes(FLEET_SIZES)
    num_steps = _smoke.steps(NUM_STEPS)
    per_fleet = {}
    fleets = [synthetic_fleet(n, seed=n) for n in sizes]
    for n, fleet in zip(sizes, fleets):
        rates = workload.synthetic_rates(n, seed=n)
        scenarios = scenario_library(rates, num_steps=num_steps, seed=SEED)
        wall_us = _time(lambda: sweep(fleet, scenarios), _smoke.reps(REPS, 2))
        res = sweep(fleet, scenarios)
        cells = len(res.policy_names) * len(res.scenario_names)
        per_fleet[n] = {
            "grid_us": wall_us,
            "us_per_step": wall_us / num_steps,
            "us_per_step_per_cell": wall_us / (num_steps * cells),
            "cells": cells,
        }

    # The batched path: every fleet size in ONE padded (F, P, W) grid,
    # sharded across jax.devices().
    rate_vectors = [workload.synthetic_rates(n, seed=n) for n in sizes]
    batched_us = _time(
        lambda: sweep_fleets(fleets, rate_vectors, num_steps=num_steps, seed=SEED),
        _smoke.reps(BATCHED_REPS, 1),
    )
    res = sweep_fleets(fleets, rate_vectors, num_steps=num_steps, seed=SEED)
    batched = {
        "grid_us": batched_us,
        "us_per_step": batched_us / num_steps,
        "fleets": len(sizes),
        "padded_width": max(sizes),
        "cells": int(res.metrics[..., 0].size),
    }

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fleet_scaling.json"), "w") as fh:
        json.dump(
            {"num_steps": num_steps, "per_fleet": per_fleet, "batched": batched},
            fh, indent=1,
        )

    lo, hi = min(sizes), max(sizes)
    growth = per_fleet[hi]["us_per_step"] / per_fleet[lo]["us_per_step"]
    return [
        f"scaling/sweep_step_n{lo},{per_fleet[lo]['us_per_step']:.1f},cells={per_fleet[lo]['cells']}",
        f"scaling/sweep_step_n{hi},{per_fleet[hi]['us_per_step']:.1f},growth_{hi // lo}x_agents={growth:.1f}x",
        f"scaling/fleet_grid,{batched_us:.1f},fleets={len(sizes)};padded_n={hi}",
    ]
