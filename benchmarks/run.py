"""Benchmark driver — one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV lines (us=0 where the benchmark is
a metric table rather than a timing).  ``--smoke`` (or
``REPRO_BENCH_SMOKE=1``) runs every module in its reduced configuration —
the CI liveness job that keeps new benchmarks from silently rotting.

``--index`` consolidates the repo-root ``BENCH_*.json`` trajectory records
into ``BENCH_index.json`` (name, date, headline wall/cell numbers) and
exits — the cheap "what do we measure and how fast is it" summary CI
regenerates on every bench-smoke run.
"""
from __future__ import annotations

import os
import sys
import traceback

# --smoke must be in the environment before the modules read it.
if "--smoke" in sys.argv[1:]:
    os.environ["REPRO_BENCH_SMOKE"] = "1"

if "--index" in sys.argv[1:]:
    from benchmarks import _bench

    print(_bench.write_index())
    sys.exit(0)

from benchmarks import (
    allocator_scaling,
    chaos_grid,
    fig2_timeseries,
    fleet_scaling,
    robustness,
    roofline,
    scaling_frontier,
    serverless_elasticity,
    serving_engine,
    sweep_grid,
    table2_metrics,
    workflow_topologies,
)

MODULES = (
    ("table2", table2_metrics),
    ("fig2", fig2_timeseries),
    ("robustness", robustness),
    ("chaos_grid", chaos_grid),
    ("sweep_grid", sweep_grid),
    ("workflow_topologies", workflow_topologies),
    ("serverless_elasticity", serverless_elasticity),
    ("allocator_scaling", allocator_scaling),
    ("fleet_scaling", fleet_scaling),
    ("roofline", roofline),
    ("serving_engine", serving_engine),
    ("scaling_frontier", scaling_frontier),
)


def main() -> None:
    # Each module resolves its own artifact dir via _smoke.out_dir(), so
    # smoke runs land in experiments/smoke/ from any entry point.
    failed = False
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        try:
            for line in mod.run():
                print(line)
        except Exception:
            failed = True
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
