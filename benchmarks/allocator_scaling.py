"""The paper's O(N) complexity claim: allocator wall time vs fleet size.

Two curves: (a) the bare Algorithm 1 call, as in the paper; (b) the same
sizes driven through the mask-aware policy registry on padded synthetic
fleets (half the slots masked off), showing the agent-validity mask adds no
asymptotic cost.  See ``benchmarks/fleet_scaling.py`` for the system-level
(full sweep per simulated step) version of the claim.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks import _smoke
from repro.core import allocator as alloc
from repro.core.agents import pad_fleet, synthetic_fleet
from repro.core.allocator import adaptive_allocation

SIZES = (4, 16, 64, 256, 1024, 4096)
REPS = 200


def _time(fn, *args) -> float:
    fn(*args).block_until_ready()  # warmup/compile
    reps = _smoke.reps(REPS, 5)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(out_dir: str | None = None) -> list[str]:
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    raw, masked = {}, {}
    for n in _smoke.sizes(SIZES):
        key = jax.random.key(n)
        lam = jax.random.uniform(key, (n,), minval=1.0, maxval=100.0)
        mins = jnp.full((n,), 0.5 / n)
        pri = jnp.ones((n,))
        f = jax.jit(lambda l, m, p: adaptive_allocation(l, m, p))
        raw[n] = _time(f, lam, mins, pri)

        # Registry path: n live agents padded into 2n masked slots.
        fleet = pad_fleet(synthetic_fleet(n, seed=n), 2 * n)
        lam_p = jnp.pad(lam, (0, n))
        zeros = jnp.zeros_like(lam_p)
        pid = jnp.asarray(alloc.policy_id("adaptive"))
        names = alloc.policy_names()
        g = jax.jit(
            lambda t, lo, le, q, fl: alloc.policy_switch(pid, t, lo, le, q, fl, 1.0, names)
        )
        masked[n] = _time(g, jnp.asarray(0), lam_p, lam_p, zeros, fleet)

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "allocator_scaling.json"), "w") as fh:
        json.dump({"raw_us": raw, "masked_registry_us": masked}, fh, indent=1)
    # sub-millisecond at paper scale; growth factor smallest -> largest size
    lo, hi = min(raw), max(raw)
    growth = raw[hi] / raw[lo]
    mgrowth = masked[hi] / masked[lo]
    factor = hi // lo
    return [
        f"scaling/alloc_n{lo},{raw[lo]:.1f},sub_ms={raw[lo] < 1000}",
        f"scaling/alloc_n{hi},{raw[hi]:.1f},growth_{factor}x_agents={growth:.1f}x",
        f"scaling/alloc_masked_n{hi},{masked[hi]:.1f},growth_{factor}x_agents={mgrowth:.1f}x",
    ]
