"""The paper's O(N) complexity claim: allocator wall time vs fleet size."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.allocator import adaptive_allocation


def run(out_dir: str = "experiments/paper") -> list[str]:
    timings = {}
    for n in (4, 16, 64, 256, 1024, 4096):
        key = jax.random.key(n)
        lam = jax.random.uniform(key, (n,), minval=1.0, maxval=100.0)
        mins = jnp.full((n,), 0.5 / n)
        pri = jnp.ones((n,))
        f = jax.jit(lambda l, m, p: adaptive_allocation(l, m, p))
        f(lam, mins, pri).block_until_ready()
        t0 = time.perf_counter()
        reps = 200
        for _ in range(reps):
            f(lam, mins, pri).block_until_ready()
        timings[n] = (time.perf_counter() - t0) / reps * 1e6

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "allocator_scaling.json"), "w") as fh:
        json.dump(timings, fh, indent=1)
    # sub-millisecond at paper scale; growth factor 4 -> 4096 agents
    growth = timings[4096] / timings[4]
    return [
        f"scaling/alloc_n4,{timings[4]:.1f},sub_ms={timings[4] < 1000}",
        f"scaling/alloc_n4096,{timings[4096]:.1f},growth_1024x_agents={growth:.1f}x",
    ]
