"""The paper's O(N) complexity claim: allocator wall time vs fleet size.

Two curves: (a) the bare Algorithm 1 call, as in the paper; (b) the same
sizes driven through the mask-aware policy registry on padded synthetic
fleets (half the slots masked off), showing the agent-validity mask adds no
asymptotic cost.  See ``benchmarks/fleet_scaling.py`` for the system-level
(full sweep per simulated step) version of the claim.

Timings land in stable-schema ``BENCH_allocator.json`` (``_bench.write``)
— one entry per (size × kernel), ``kernel`` ∈ {``allocator_raw``,
``allocator_masked_registry``} — replacing the old ad-hoc
``allocator_scaling.json`` dict so the numbers are diffable against future
PRs like every other perf surface.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import _bench, _smoke
from repro.core import allocator as alloc
from repro.core.agents import pad_fleet, synthetic_fleet
from repro.core.allocator import adaptive_allocation

SIZES = (4, 16, 64, 256, 1024, 4096)
REPS = 200


def run(out_dir: str | None = None) -> list[str]:
    reps = _smoke.reps(REPS, 5)
    raw, masked = {}, {}
    entries = []
    for n in _smoke.sizes(SIZES):
        key = jax.random.key(n)
        lam = jax.random.uniform(key, (n,), minval=1.0, maxval=100.0)
        mins = jnp.full((n,), 0.5 / n)
        pri = jnp.ones((n,))
        f = jax.jit(lambda l, m, p: adaptive_allocation(l, m, p))
        raw[n] = _bench.time_device(lambda: f(lam, mins, pri), reps)
        entries.append(_bench.timing_entry(
            f"n{n}", "allocator_raw", n, 1, 1, raw[n]
        ))

        # Registry path: n live agents padded into 2n masked slots.
        fleet = pad_fleet(synthetic_fleet(n, seed=n), 2 * n)
        lam_p = jnp.pad(lam, (0, n))
        zeros = jnp.zeros_like(lam_p)
        pid = jnp.asarray(alloc.policy_id("adaptive"))
        names = alloc.policy_names()
        g = jax.jit(
            lambda t, lo, le, q, fl: alloc.policy_switch(pid, t, lo, le, q, fl, 1.0, names)
        )
        masked[n] = _bench.time_device(
            lambda: g(jnp.asarray(0), lam_p, lam_p, zeros, fleet), reps
        )
        entries.append(_bench.timing_entry(
            f"n{n}", "allocator_masked_registry", n, 1, 1, masked[n],
            padded_slots=2 * n,
        ))

    _bench.write("allocator", entries, out_dir=out_dir)
    # sub-millisecond at paper scale; growth factor smallest -> largest size
    lo, hi = min(raw), max(raw)
    growth = raw[hi] / raw[lo]
    mgrowth = masked[hi] / masked[lo]
    factor = hi // lo
    return [
        f"scaling/alloc_n{lo},{raw[lo]:.1f},sub_ms={raw[lo] < 1000}",
        f"scaling/alloc_n{hi},{raw[hi]:.1f},growth_{factor}x_agents={growth:.1f}x",
        f"scaling/alloc_masked_n{hi},{masked[hi]:.1f},growth_{factor}x_agents={mgrowth:.1f}x",
    ]
