"""Paper Fig. 2 (a-d): per-agent latency, throughput, allocation-over-time,
and the cost-performance scatter.  Emits the plot data as JSON."""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks import _smoke
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.simulator import simulate, summarize


def run(out_dir: str | None = None) -> list[str]:
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    fleet = paper_fleet()
    arr = workload.constant(jnp.asarray(PAPER_ARRIVAL_RATES), _smoke.steps(100))
    data = {"agents": list(fleet.names)}
    scatter = []
    for policy in ("static_equal", "round_robin", "adaptive"):
        tr = simulate(policy, arr, fleet)
        s = summarize(policy, tr)
        data[policy] = {
            "fig2a_per_agent_latency": [round(x, 1) for x in s.per_agent_latency],
            "fig2b_per_agent_throughput": [round(x, 2) for x in s.per_agent_throughput],
            "fig2c_allocation_over_time": np.asarray(tr.allocation).round(4).tolist(),
            "queue_over_time": np.asarray(tr.queue).round(1).tolist(),
        }
        scatter.append({"policy": policy, "latency": round(s.avg_latency, 1),
                        "throughput": round(s.total_throughput, 2),
                        "cost": round(s.cost, 3)})
    data["fig2d_cost_performance"] = scatter

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2.json"), "w") as fh:
        json.dump(data, fh)

    # Fig 2(c) stability check: adaptive allocation curves are smooth.
    g = np.asarray(simulate("adaptive", arr, fleet).allocation)
    osc = float(np.abs(np.diff(g, axis=0)).max())
    return [f"fig2/alloc_stability,0,max_step_change={osc:.4f}"]
