"""Cost–latency Pareto frontier under serverless elasticity — the paper's
cost-efficiency claim made non-vacuous.

Under a provisioned device every policy costs the same and the paper's
"cost-efficient" verdict is vacuous; under the warm-pool capacity layer
(``core/capacity.py``) billing is warm-instance-seconds, so each
(allocation policy × capacity policy × scenario) cell has its *own* cost.
This benchmark runs one jitted (capacity × policy × scenario) grid over the
paper's Table I fleet with an 8-instance ceiling and reports, per scenario:

* the cost–latency Pareto frontier over all (capacity, policy) pairs —
  which combinations buy latency with warm instances efficiently,
* the cost *spread* across allocation policies within each capacity policy
  (zero under ``fixed``, strictly positive under elastic capacity: the
  allocator's serving decisions feed back into the autoscaler), and
* cold-start stall seconds and mean warm-pool size per capacity policy.

Writes ``experiments/paper/serverless_elasticity.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp

from benchmarks import _smoke
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.simulator import METRIC_NAMES, SimConfig
from repro.core.sweep import (
    Scenario,
    capacity_scenario_library,
    scenario_library,
    sweep_capacity,
)

NUM_GPUS = 8.0


def _idle_gap(rates, num_steps: int) -> jnp.ndarray:
    """Constant arrivals with a dead middle third — the only scenario in
    which a pool may go fully idle, so ``scale_to_zero`` separates from
    ``reactive_cold`` (everywhere else some backlog keeps one instance
    warm through the keep-alive window)."""
    arr = workload.constant(jnp.asarray(rates, jnp.float32), num_steps)
    t = jnp.arange(num_steps)[:, None]
    gap = (t >= num_steps // 3) & (t < 2 * num_steps // 3)
    return jnp.where(gap, 0.0, arr)


def _pareto_front(points: list[dict]) -> list[dict]:
    """Non-dominated subset under (min cost, min avg_latency)."""
    front = []
    for p in points:
        dominated = any(
            (q["cost"] <= p["cost"] and q["avg_latency"] <= p["avg_latency"])
            and (q["cost"] < p["cost"] or q["avg_latency"] < p["avg_latency"])
            for q in points
        )
        if not dominated:
            front.append(p)
    return sorted(front, key=lambda p: p["cost"])


def run(out_dir: str | None = None) -> list[str]:
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    fleet = paper_fleet()
    num_steps = _smoke.steps(100)
    config = SimConfig(g_total=1.0, num_gpus=NUM_GPUS)
    capacities = capacity_scenario_library()
    scenarios = scenario_library(PAPER_ARRIVAL_RATES, num_steps=num_steps, seed=0)
    scenarios = scenarios + (
        Scenario("idle_gap", _idle_gap(PAPER_ARRIVAL_RATES, num_steps)),
    )

    grid = lambda: sweep_capacity(fleet, capacities, scenarios, config=config)
    res = grid()  # warmup: compiles the whole (C, P, W) program
    t0 = time.perf_counter()
    res = grid()
    us = (time.perf_counter() - t0) * 1e6

    cost = res.metric("cost")          # (C, P, W)
    lat = res.metric("avg_latency")
    stall = res.metric("cold_start_stall_time")
    warm = res.metric("mean_warm_instances")

    pareto = {}
    cost_spread = {}
    for w, scen in enumerate(res.scenario_names):
        points = [
            {
                "capacity": cn, "policy": pn,
                "cost": float(cost[c, p, w]),
                "avg_latency": float(lat[c, p, w]),
            }
            for c, cn in enumerate(res.capacity_names)
            for p, pn in enumerate(res.policy_names)
        ]
        pareto[scen] = _pareto_front(points)
        cost_spread[scen] = {
            cn: float(cost[c, :, w].max() - cost[c, :, w].min())
            for c, cn in enumerate(res.capacity_names)
        }

    table = res.table()
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "serverless_elasticity.json"), "w") as fh:
        json.dump(
            {
                "num_steps": num_steps,
                "num_gpus_ceiling": NUM_GPUS,
                "capacities": list(res.capacity_names),
                "policies": list(res.policy_names),
                "scenarios": list(res.scenario_names),
                "metric_names": list(METRIC_NAMES),
                "grid_us": us,
                "pareto_front": pareto,
                "cost_spread_across_policies": cost_spread,
                "mean_warm_instances": {
                    cn: float(warm[c].mean())
                    for c, cn in enumerate(res.capacity_names)
                },
                "cold_start_stall_s": {
                    cn: float(stall[c].mean())
                    for c, cn in enumerate(res.capacity_names)
                },
                "rows": [dict(zip(table.columns, row)) for row in table.rows],
            },
            fh, indent=1,
        )

    c_n, p_n, w_n = (len(res.capacity_names), len(res.policy_names),
                     len(res.scenario_names))
    out = [f"elasticity/grid,{us:.1f},cells={c_n * p_n * w_n}"]
    for c, cn in enumerate(res.capacity_names):
        out.append(
            f"elasticity/{cn},0,"
            f"cost={cost[c].mean():.4f};warm={warm[c].mean():.2f};"
            f"stall_s={stall[c].mean():.1f}"
        )
    # The acceptance headline: elastic capacity makes cost policy-dependent.
    for scen in ("diurnal", "bursty"):
        spread = max(
            v for k, v in cost_spread[scen].items() if k != "fixed"
        )
        out.append(f"elasticity/cost_spread_{scen},0,{spread:.5f}")
    return out
