"""Paper §V-B: 3x overload degradation, 10x spike adaptation speed,
single-agent domination containment."""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.simulator import run_policy, simulate


def run(out_dir: str = "experiments/paper") -> list[str]:
    fleet = paper_fleet()
    rates = jnp.asarray(PAPER_ARRIVAL_RATES)
    res = {}

    # (1) demand 3x capacity: graceful degradation, no starvation.
    base = run_policy("adaptive", workload.constant(rates, 100), fleet)
    over = run_policy("adaptive", workload.scaled(rates, 100, 3.0), fleet)
    res["overload_3x"] = {
        "base_latency": round(base.avg_latency, 1),
        "overload_latency": round(over.avg_latency, 1),
        "latency_degradation_pct": round(100 * (over.avg_latency / base.avg_latency - 1), 1),
        "min_agent_throughput": round(min(over.per_agent_throughput), 2),
    }

    # (2) 10x spike: how many steps until the spiked agent's allocation
    # reaches 95% of its new steady-state share (paper: within 100 ms).
    arr = workload.spike(rates, 100, spike_agent=3, spike_start=50, spike_len=30)
    tr = simulate("adaptive", arr, fleet)
    g = np.asarray(tr.allocation)[:, 3]
    steady = g[70]
    steps = int(np.argmax(g[50:71] >= 0.95 * steady))
    res["spike_10x"] = {
        "pre_spike_alloc": round(float(g[49]), 4),
        "post_spike_alloc": round(float(steady), 4),
        "steps_to_95pct": steps,
    }

    # (3) one agent with 90% of requests must not monopolize the GPU.
    tr = simulate("adaptive", workload.dominated(rates, 100, agent=0, share=0.9), fleet)
    gm = np.asarray(tr.allocation).mean(0)
    res["domination_90pct"] = {
        "dominant_agent_share": round(float(gm[0]), 3),
        "min_other_share": round(float(gm[1:].min()), 3),
    }

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "robustness.json"), "w") as fh:
        json.dump(res, fh, indent=1)
    return [
        f"robustness/overload,0,degradation={res['overload_3x']['latency_degradation_pct']}%",
        f"robustness/spike,0,steps={res['spike_10x']['steps_to_95pct']}",
        f"robustness/domination,0,max_share={res['domination_90pct']['dominant_agent_share']}",
    ]
