"""Paper §V-B: 3x overload degradation, 10x spike adaptation speed,
single-agent domination containment — all four scenarios evaluated in one
vmapped sweep call (traces kept for the time-series checks)."""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks import _smoke
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.sweep import Scenario, sweep


def run(out_dir: str | None = None) -> list[str]:
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    fleet = paper_fleet()
    rates = jnp.asarray(PAPER_ARRIVAL_RATES)
    steps = _smoke.steps(100)
    spike_start, spike_len = steps // 2, 3 * steps // 10
    scenarios = (
        Scenario("constant", workload.constant(rates, steps)),
        Scenario("overload_3x", workload.scaled(rates, steps, 3.0)),
        Scenario("spike_10x",
                 workload.spike(rates, steps, spike_agent=3,
                                spike_start=spike_start, spike_len=spike_len)),
        Scenario("dominated",
                 workload.dominated(rates, steps, agent=0, share=0.9)),
    )
    res = sweep(fleet, scenarios, policies=("adaptive",), keep_traces=True)
    alloc_grid = np.asarray(res.traces.allocation)  # (1, W, S, N)
    w = {name: i for i, name in enumerate(res.scenario_names)}
    out = {}

    # (1) demand 3x capacity: graceful degradation, no starvation.
    base = res.summary("adaptive", "constant")
    over = res.summary("adaptive", "overload_3x")
    out["overload_3x"] = {
        "base_latency": round(base.avg_latency, 1),
        "overload_latency": round(over.avg_latency, 1),
        "latency_degradation_pct": round(100 * (over.avg_latency / base.avg_latency - 1), 1),
        "min_agent_throughput": round(min(over.per_agent_throughput), 2),
    }

    # (2) 10x spike: how many steps until the spiked agent's allocation
    # reaches 95% of its new steady-state share (paper: within 100 ms).
    g = alloc_grid[0, w["spike_10x"], :, 3]
    steady_at = spike_start + spike_len - spike_len // 3  # well inside the spike
    steady = g[steady_at]
    adapt = int(np.argmax(g[spike_start:steady_at + 1] >= 0.95 * steady))
    out["spike_10x"] = {
        "pre_spike_alloc": round(float(g[spike_start - 1]), 4),
        "post_spike_alloc": round(float(steady), 4),
        "steps_to_95pct": adapt,
    }

    # (3) one agent with 90% of requests must not monopolize the GPU.
    gm = alloc_grid[0, w["dominated"]].mean(0)
    out["domination_90pct"] = {
        "dominant_agent_share": round(float(gm[0]), 3),
        "min_other_share": round(float(gm[1:].min()), 3),
    }

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "robustness.json"), "w") as fh:
        json.dump(out, fh, indent=1)
    return [
        f"robustness/overload,0,degradation={out['overload_3x']['latency_degradation_pct']}%",
        f"robustness/spike,0,steps={out['spike_10x']['steps_to_95pct']}",
        f"robustness/domination,0,max_share={out['domination_90pct']['dominant_agent_share']}",
    ]
