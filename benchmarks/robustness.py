"""Paper §V-B: 3x overload degradation, 10x spike adaptation speed,
single-agent domination containment — every registered policy evaluated
against all four scenarios in one vmapped sweep call (traces kept for the
time-series checks).

Timing blocks on the jitted device output (``jax.block_until_ready`` via
``return_arrays=True``) so the headline number measures device work, not
dispatch + host copy.  Writes ``experiments/paper/robustness.json`` and
the stable-schema ``BENCH_robustness.json`` at the repo root (see
``benchmarks/_bench.py``; smoke runs are held to the RSS budget there)."""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks import _bench, _smoke
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.sweep import Scenario, sweep

REPS = 10


def run(out_dir: str | None = None) -> list[str]:
    bench_dir = out_dir  # explicit destination redirects BENCH files too
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    fleet = paper_fleet()
    rates = jnp.asarray(PAPER_ARRIVAL_RATES)
    steps = _smoke.steps(100)
    spike_start, spike_len = steps // 2, 3 * steps // 10
    scenarios = (
        Scenario("constant", workload.constant(rates, steps)),
        Scenario("overload_3x", workload.scaled(rates, steps, 3.0)),
        Scenario("spike_10x",
                 workload.spike(rates, steps, spike_agent=3,
                                spike_start=spike_start, spike_len=spike_len)),
        Scenario("dominated",
                 workload.dominated(rates, steps, agent=0, share=0.9)),
    )
    reps = _smoke.reps(REPS, 2)
    wall = _bench.time_device(
        lambda: sweep(fleet, scenarios, return_arrays=True), reps
    )
    res = sweep(fleet, scenarios, keep_traces=True)
    alloc_grid = np.asarray(res.traces.allocation)  # (P, W, S, N)
    w = {name: i for i, name in enumerate(res.scenario_names)}
    pols = res.policy_names
    out = {"policies": list(pols)}

    # (1) demand 3x capacity: graceful degradation, no starvation —
    # per-policy latency blow-up and worst-served agent.
    out["overload_3x"] = {}
    for pol in pols:
        base = res.summary(pol, "constant")
        over = res.summary(pol, "overload_3x")
        out["overload_3x"][pol] = {
            "base_latency": round(base.avg_latency, 1),
            "overload_latency": round(over.avg_latency, 1),
            "latency_degradation_pct": round(
                100 * (over.avg_latency / (base.avg_latency or 1.0) - 1), 1),
            "min_agent_throughput": round(min(over.per_agent_throughput), 2),
        }

    # (2) 10x spike: how many steps until the spiked agent's allocation
    # reaches 95% of its new steady-state share (paper: within 100 ms).
    # Static policies never move, so their entry reports the share gap
    # instead of a fake adaptation time.
    steady_at = spike_start + spike_len - spike_len // 3  # inside the spike
    out["spike_10x"] = {}
    for p, pol in enumerate(pols):
        g = alloc_grid[p, w["spike_10x"], :, 3]
        steady = g[steady_at]
        pre = float(g[spike_start - 1])
        moved = abs(float(steady) - pre) > 1e-6
        adapt = (
            int(np.argmax(g[spike_start:steady_at + 1] >= 0.95 * steady))
            if moved else None
        )
        out["spike_10x"][pol] = {
            "pre_spike_alloc": round(pre, 4),
            "post_spike_alloc": round(float(steady), 4),
            "steps_to_95pct": adapt,
        }

    # (3) one agent with 90% of requests must not monopolize the GPU.
    out["domination_90pct"] = {}
    for p, pol in enumerate(pols):
        gm = alloc_grid[p, w["dominated"]].mean(0)
        out["domination_90pct"][pol] = {
            "dominant_agent_share": round(float(gm[0]), 3),
            "min_other_share": round(float(gm[1:].min()), 3),
        }

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "robustness.json"), "w") as fh:
        json.dump(out, fh, indent=1)
    cells = len(pols) * len(res.scenario_names)
    _bench.write("robustness", [
        _bench.timing_entry(
            "paper_fleet_4scen", "streaming", fleet.num_agents, steps,
            cells, wall,
        )
    ], out_dir=bench_dir)

    ad = out["overload_3x"]["adaptive"]
    sp = out["spike_10x"]["adaptive"]
    dom = out["domination_90pct"]["adaptive"]
    worst_deg = max(
        out["overload_3x"].items(),
        key=lambda kv: kv[1]["latency_degradation_pct"],
    )
    return [
        f"robustness/grid,{wall:.1f},cells={cells}",
        f"robustness/overload,0,degradation={ad['latency_degradation_pct']}%",
        f"robustness/spike,0,steps={sp['steps_to_95pct']}",
        f"robustness/domination,0,max_share={dom['dominant_agent_share']}",
        (
            f"robustness/worst_overload,0,policy={worst_deg[0]};"
            f"degradation={worst_deg[1]['latency_degradation_pct']}%"
        ),
    ]
