"""§Roofline table assembly from the dry-run / unit-analysis artifacts.

Reads experiments/roofline/*.json (scan-corrected, per-device) and
experiments/dryrun/*.json (whole-step compile proof + memory_analysis) and
emits the markdown table embedded in EXPERIMENTS.md — plus, whenever rows
exist, a stable-schema ``BENCH_roofline.json`` (one entry per arch/shape
with the modeled compute/memory/collective seconds and bottleneck) so the
roofline numbers are machine-diffable against future PRs instead of living
only in a markdown table.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import _bench, _smoke
from repro.launch.mesh import HW

MOVE_DOWN = {
    "compute": "shard/strengthen the matmul path (more model-parallel FLOP/s)",
    "memory": "fuse or shrink activation traffic; bf16 intermediates; smaller capacity buffers",
    "collective": "resharding: avoid weight gathers / reduce partial-sum all-reduces",
}


def load_rows(pattern: str = "experiments/roofline/*_pod1.json") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(pattern)):
        base = os.path.basename(f)
        # skip hillclimb variants in the baseline table
        if any(t in base for t in ("_serve_v2", "_serve_v3", "_serve_ep", "_grouped", "_cap", "_noseq")):
            continue
        d = json.load(open(f))
        if "roofline_s" in d:
            rows.append(d)
    return rows


def markdown_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck | "
        "MODEL_FLOPS | useful ratio | what moves the bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for d in rows:
        r = d["roofline_s"]
        out.append(
            f"| {d['arch']} | {d['shape']} | {r['compute']:.4f} | {r['memory']:.4f} "
            f"| {r['collective']:.4f} | {d['bottleneck']} | {d['model_flops_global']:.2e} "
            f"| {d['useful_flops_ratio']:.3f} | {MOVE_DOWN[d['bottleneck']]} |"
        )
    return "\n".join(out)


def bench_entries(rows: list[dict]) -> list[dict]:
    """Roofline rows in the flat ``_bench`` entry shape: modeled seconds
    per resource (not a timing loop, so ``wall_us`` carries the bottleneck
    resource's modeled time — the step-time floor the model predicts)."""
    entries = []
    for d in rows:
        r = d["roofline_s"]
        entries.append({
            "grid": f"{d['arch']}/{d['shape']}",
            "kernel": "roofline_model",
            "wall_us": r[d["bottleneck"]] * 1e6,
            "compute_s": r["compute"],
            "memory_s": r["memory"],
            "collective_s": r["collective"],
            "bottleneck": d["bottleneck"],
            "model_flops_global": d["model_flops_global"],
            "useful_flops_ratio": d["useful_flops_ratio"],
        })
    return entries


def run(out_dir: str | None = None) -> list[str]:
    table_dir = _smoke.out_dir() if out_dir is None else out_dir
    rows = load_rows()
    os.makedirs(table_dir, exist_ok=True)
    with open(os.path.join(table_dir, "roofline_table.md"), "w") as fh:
        fh.write(markdown_table(rows) + "\n")
    # Always write the BENCH file: an empty-rows run emits an explicit
    # empty record rather than silently leaving a stale (or absent) file —
    # downstream diffing ("did the roofline disappear?") needs the
    # distinction between "not run" and "run, no artifacts".
    _bench.write("roofline", bench_entries(rows), out_dir=out_dir)
    if not rows:
        return ["roofline/table,0,rows=0 (run repro.launch.roofline first)"]
    worst = min(rows, key=lambda d: d["useful_flops_ratio"])
    bn = {}
    for d in rows:
        bn[d["bottleneck"]] = bn.get(d["bottleneck"], 0) + 1
    return [
        f"roofline/table,0,rows={len(rows)};bottlenecks={bn}",
        f"roofline/worst_useful,0,{worst['arch']}/{worst['shape']}={worst['useful_flops_ratio']:.3f}",
    ]
