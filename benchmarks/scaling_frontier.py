"""Weak/strong scaling of the 2D-sharded streaming sweep to million-cell
grids — the ``BENCH_scaling.json`` writer.

The sweep mesh (``core/sharding.py``) is exercised at 1/2/4/8 **forced host
devices**: the XLA flag is consumed once at backend initialization, so each
device count runs in its own subprocess (``sharding.host_device_env``) and
reports its timings back over stdout.  Four grid families:

* ``strong`` — one fixed (F × P × W) grid at every device count; ideal
  strong scaling halves the wall time per doubling.
* ``weak`` — per-device work held constant (F ∝ devices); ideal weak
  scaling holds the wall time flat.
* ``scenario_major`` — the grid shape the old 1D layout handled worst: a
  tiny fleet axis that never divides the device count, so the whole grid
  fell back to replication (every device computing every cell).  Measured
  both ways at the top device count: the 2D mesh shards the scenario axis
  instead, and the entry pair records the honest speedup.
* ``frontier`` — the N=10⁴-fleet grid and a million-cell (F·P·W > 10⁶)
  grid, streaming + 2D-sharded, with the replicated-1D baseline measured
  alongside at 10⁴ fleets; plus a 10⁵-step scenario-axis horizon grid
  through the plain ``sweep`` entry point (horizon-independent memory is
  what makes it feasible at all).
* ``block_sweep`` — the time-blocked kernel's B-sweep: the full-registry
  grid at S = 10⁵ for B ∈ {1, 8, 32, 128} × synth/materialized, each row
  compiled cold through ``_bench.compile_probe`` so ``compile_s`` records
  the one-time cost the block size buys its throughput with.  Synth rows
  run before materialized rows so their ``max_rss_bytes`` marks stay
  attributable.
* ``horizon_synth`` / ``horizon_mat`` — the in-scan-synthesis payoff pair
  at S = 10⁶ steps: the full scenario registry as ``WorkloadSpec`` columns
  synthesized inside the scan (O(W·N) input memory) versus the same specs
  materialized to a (W, S, N) tensor first (the materialization runs
  inside the timed region — it is exactly the producer cost synthesis
  eliminates).  Each arm is measured at B = 1 and again at the best B its
  own ``block_sweep`` rows measured (resolved worker-side, recorded in the
  row's ``block_size``).  Within each arm the B = 1 row runs *first* so
  the blocked row's ``max_rss_bytes`` is comparable against it.
* ``widefleet`` — the honest memory frontier: a fleet wide enough that the
  materialized S = 10⁶ arrivals tensor exceeds physical host RAM.  The
  materialized arm is **refused** (an entry with ``status`` and
  ``required_bytes`` > ``available_bytes`` — no timing, the allocation
  cannot exist), while the synthesis arm is measured at the same width
  over a shorter *probe* horizon (its memory is O(1) in S, so only wall
  time — ~14 ms/step/80k-lanes on this host — caps the probe).
* ``policy_axis`` — strong scaling over the third mesh axis at the top
  device count: a deliberately narrow scenario axis (W=2) that starves
  the 2D layout, re-run with dp ∈ divisors so the (P, N) policy-stack
  rows split across the ``policy`` axis instead.

Timed regions contain kernel work only (fleet/scenario construction is
hoisted, as in ``fleet_scaling.py``), block on device output via
``_bench.time_device``, and — because the 2D kernel *donates* its arrivals
block — rebuild the donated buffer inside the timed function, exactly the
cost a fresh-arrivals producer pays.  Every entry lands in the stable
``_bench`` schema with its own ``device_count``/``host_cpus``; wall-clock
caveat: on a host with fewer physical cores than forced devices the
device blocks time-slice, so strong/weak curves flatten — the
``scenario_major`` pair stays meaningful there because the replicated
baseline burns ``device_count×`` *total* work, not just wall time.

``--smoke`` (or ``REPRO_BENCH_SMOKE=1``) shrinks to 2 device counts and
liveness-sized grids; the JSON then goes to ``experiments/smoke/`` (CI
uploads it as an artifact).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SENTINEL = "SCALING_JSON:"
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DEVICE_COUNTS = (1, 2, 4, 8)
STRONG_FLEETS = 64
WEAK_FLEETS_PER_DEVICE = 16
SCENARIO_MAJOR_FLEETS = 2      # deliberately never divides the device count
FRONTIER_FLEETS = 10_000
MILLION_CELL_FLEETS = 18_000   # 18_000 · 7 policies · 8 scenarios > 10⁶ cells
HORIZON_STEPS = 100_000
HORIZON_FRONTIER_STEPS = 1_000_000
BLOCK_SWEEP_STEPS = 100_000
BLOCK_SWEEP_SIZES = (1, 8, 32, 128)
SMOKE_BLOCK_SIZES = (1, 8)
WIDE_AGENTS = 40_960           # (1, 10⁶, 40960) f32 = 164 GB: exceeds host RAM
WIDE_STEPS = 1_000_000
WIDE_PROBE_STEPS = 20_000      # synth probe horizon: memory is O(1) in S,
                               # only the ~14 ms/step wall caps the probe
POLICY_AXIS_STEPS = 1_000
POLICY_AXIS_SCENARIOS = 2      # narrow scenario axis: starves the 2D layout
NUM_STEPS = 200
FRONTIER_STEPS = 50
AGENTS = 8
FRONTIER_AGENTS = 4
REPS = 3
WORKER_TIMEOUT_S = 7200


def _policy_axis_widths(device_count: int) -> tuple[int, ...]:
    return tuple(k for k in (1, 2, 4, 8) if device_count % k == 0
                 and k <= device_count)


def _tasks(device_count: int, max_devices: int, smoke: bool) -> list[dict]:
    """The grid family list one worker process runs."""
    steps = 20 if smoke else NUM_STEPS
    reps = 1 if smoke else REPS
    strong_f = 8 if smoke else STRONG_FLEETS
    weak_f = (4 if smoke else WEAK_FLEETS_PER_DEVICE) * device_count
    tasks = []
    if device_count == 1:
        # Memory-frontier grids are a per-host story: single device, and
        # first in the worker so each arm's max_rss high-water mark is
        # attributable (synth before materialized, both before anything
        # bigger).
        h_steps = 1_000 if smoke else HORIZON_FRONTIER_STEPS
        b_steps = 500 if smoke else BLOCK_SWEEP_STEPS
        b_sizes = SMOKE_BLOCK_SIZES if smoke else BLOCK_SWEEP_SIZES
        # Per arm: the S=1e5 B-sweep, then the S=1e6 row at B=1, then the
        # S=1e6 row at the best B the sweep measured — synth family first
        # so every one of its max_rss marks precedes the bigger
        # materialized buffers.
        for arm in ("synth", "mat"):
            mode = f"{arm}_horizon"
            for b in b_sizes:
                tasks.append(dict(grid="block_sweep_1e5", mode=mode,
                                  fleets=1, agents=FRONTIER_AGENTS,
                                  num_steps=b_steps, reps=1, block_size=b))
            tasks.append(dict(grid=f"horizon_{arm}_1e6", mode=mode,
                              fleets=1, agents=FRONTIER_AGENTS,
                              num_steps=h_steps, reps=1, block_size=1))
            tasks.append(dict(grid=f"horizon_{arm}_1e6_bestB", mode=mode,
                              fleets=1, agents=FRONTIER_AGENTS,
                              num_steps=h_steps, reps=1, block_size="best"))
        wide_n = 2_048 if smoke else WIDE_AGENTS
        tasks.append(dict(grid="widefleet_synth_probe", mode="synth_wide",
                          fleets=1, agents=wide_n,
                          num_steps=50 if smoke else WIDE_PROBE_STEPS,
                          reps=1))
        tasks.append(dict(grid="widefleet_mat_1e6", mode="refusal_mat",
                          fleets=1, agents=WIDE_AGENTS,
                          num_steps=WIDE_STEPS, reps=0))
    tasks += [
        dict(grid="strong", mode="default", fleets=strong_f, agents=AGENTS,
             num_steps=steps, reps=reps),
        dict(grid="weak", mode="default", fleets=weak_f, agents=AGENTS,
             num_steps=steps, reps=reps),
    ]
    if device_count == max_devices:
        sm_f = SCENARIO_MAJOR_FLEETS
        tasks.append(dict(grid="scenario_major", mode="default", fleets=sm_f,
                          agents=AGENTS, num_steps=steps, reps=reps))
        tasks.append(dict(grid="scenario_major", mode="replicated_1d",
                          fleets=sm_f, agents=AGENTS, num_steps=steps,
                          reps=reps))
        for dp in _policy_axis_widths(device_count):
            tasks.append(dict(grid="policy_axis", mode="policy_axis",
                              fleets=1, agents=AGENTS,
                              num_steps=50 if smoke else POLICY_AXIS_STEPS,
                              reps=reps, policy_devices=dp))
        if not smoke:
            tasks.append(dict(grid="frontier_10k", mode="default",
                              fleets=FRONTIER_FLEETS, agents=FRONTIER_AGENTS,
                              num_steps=FRONTIER_STEPS, reps=1))
            tasks.append(dict(grid="frontier_10k", mode="replicated_1d",
                              fleets=FRONTIER_FLEETS, agents=FRONTIER_AGENTS,
                              num_steps=FRONTIER_STEPS, reps=1))
            tasks.append(dict(grid="million_cell", mode="default",
                              fleets=MILLION_CELL_FLEETS,
                              agents=FRONTIER_AGENTS,
                              num_steps=FRONTIER_STEPS, reps=1))
            tasks.append(dict(grid="horizon_1e5", mode="scenario_axis",
                              fleets=1, agents=FRONTIER_AGENTS,
                              num_steps=HORIZON_STEPS, reps=1))
    return tasks


# -- worker side (runs once per forced device count) -------------------------


def _worker(cfg: dict) -> dict:
    import jax
    import jax.numpy as jnp

    from benchmarks import _bench
    import importlib

    from repro.core import allocator as alloc
    from repro.core import sharding, workload

    # ``from repro.core import sweep`` yields the re-exported *function*
    # (the package __init__ shadows the submodule name); the kernels live
    # on the module itself.
    sweep_mod = importlib.import_module("repro.core.sweep")
    from repro.core.agents import synthetic_fleet
    from repro.core.simulator import SimConfig
    from repro.core.sweep import scenario_library, sweep

    assert jax.device_count() == cfg["device_count"], jax.devices()
    names = alloc.policy_names()
    config = SimConfig()
    entries = []
    for task in cfg["tasks"]:
        f, n = task["fleets"], task["agents"]
        steps, reps = task["num_steps"], task["reps"]
        if task["mode"] == "refusal_mat":
            # The materialized arrivals tensor for this configuration cannot
            # exist on this host: record the refusal with the arithmetic
            # instead of OOM-killing the worker.  Even a single scenario
            # column ((1, S, N) float32 — same W=1 shape the synthesis arm
            # runs as ``widefleet_synth_probe``) exceeds physical RAM.
            required = steps * n * 4
            available = os.sysconf("SC_PHYS_PAGES") * os.sysconf("SC_PAGE_SIZE")
            entries.append({
                "grid": task["grid"], "kernel": "streaming_materialized",
                "n": n, "num_steps": steps, "cells": 1,
                "wall_us": None, "us_per_step": None,
                "us_per_step_per_cell": None, "peak_device_bytes": None,
                "status": "refused: materialized arrivals tensor exceeds host RAM",
                "required_bytes": required, "available_bytes": int(available),
                "device_count": cfg["device_count"],
                "host_cpus": os.cpu_count(),
            })
            continue
        fleet = synthetic_fleet(n, seed=0)
        if task["mode"] in ("synth_horizon", "mat_horizon"):
            # The block-sweep rows and the S=10⁶ payoff pair: same full
            # scenario registry, one arm synthesizing rows inside the scan,
            # the other materializing the (W, S, N) tensor inside the timed
            # region — the producer cost synthesis eliminates.  Every row
            # compiles cold through ``compile_probe`` and times the AOT
            # object, so ``compile_s`` is honest and the timed region pays
            # no hidden recompile.
            synth = task["mode"] == "synth_horizon"
            kernel = "streaming_synth" if synth else "streaming_materialized"
            bsz = task.get("block_size", 1)
            if bsz == "best":
                # Resolved worker-side, per arm: the B whose own
                # ``block_sweep`` row ran fastest earlier in this process.
                cands = [e for e in entries
                         if e["grid"] == "block_sweep_1e5"
                         and e["kernel"] == kernel]
                bsz = (min(cands, key=lambda e: e["wall_us"])["block_size"]
                       if cands else 1)
            specs = workload.scenario_specs(
                workload.synthetic_rates(n, seed=0), num_steps=steps, seed=0
            )
            cells = f * len(names) * len(specs)
            if synth:
                # Grouped static generator dispatch — the same fast path
                # the public ``sweep`` entry point takes on one device.
                stack = workload.stack_specs(specs)
                compile_s, compiled = _bench.compile_probe(
                    sweep_mod._stream_grid_jit,
                    None, fleet, None, None, stack, None, config, names,
                    None, 1, bsz, sweep_mod.synth_gen_groups(stack),
                )
                fn = lambda: compiled(None, fleet, None, None, stack, None)
            else:
                arr = jnp.stack([workload.materialize(s) for s in specs])
                compile_s, compiled = _bench.compile_probe(
                    sweep_mod._stream_grid_jit,
                    arr, fleet, None, None, None, None, config, names, None,
                    1, bsz,
                )
                del arr
                fn = lambda: compiled(
                    jnp.stack([workload.materialize(s) for s in specs]),
                    fleet, None, None, None, None,
                )
            wall_us = _bench.time_device(fn, reps)
            entries.append(_bench.timing_entry(
                task["grid"], kernel, n, steps, cells, wall_us,
                block_size=bsz, compile_s=compile_s,
                device_count=cfg["device_count"], host_cpus=os.cpu_count(),
                fleets=f, max_rss_bytes=_bench.max_rss_bytes(),
                arrivals_bytes_if_materialized=len(specs) * steps * n * 4,
            ))
            continue
        if task["mode"] == "synth_wide":
            # Synthesis at the refused width: a single cheap time-varying
            # generator (diurnal — no per-step RNG) over one policy, probe
            # horizon (memory is O(1) in S; see module docstring).
            spec = workload.diurnal_spec(
                workload.synthetic_rates(n, seed=0), num_steps=steps
            )
            stack = workload.stack_specs([spec])
            sub = names[:1]
            cells = f * len(sub)
            fn = lambda: sweep_mod._stream_grid_jit(
                None, fleet, None, None, stack, None, config, sub, None,
                gen_groups=sweep_mod.synth_gen_groups(stack),
            )
            wall_us = _bench.time_device(fn, task["reps"])
            entries.append(_bench.timing_entry(
                task["grid"], "streaming_synth", n, steps, cells, wall_us,
                device_count=cfg["device_count"], host_cpus=os.cpu_count(),
                fleets=f, max_rss_bytes=_bench.max_rss_bytes(),
                probe_of_num_steps=WIDE_STEPS,
                arrivals_bytes_if_materialized=steps * n * 4,
            ))
            continue
        if task["mode"] == "policy_axis":
            # dp-way policy-axis split on a scenario axis too narrow for
            # the 2D layout (W=2): the (P, N) policy rows shard over the
            # mesh's third axis, names padded to divisibility inside
            # ``_run_stream_sharded``.
            dp = task["policy_devices"]
            specs = workload.scenario_specs(
                workload.synthetic_rates(n, seed=0), num_steps=steps, seed=0
            )[:POLICY_AXIS_SCENARIOS]
            stack = workload.stack_specs(specs)
            cells = f * len(names) * len(specs)
            if cfg["device_count"] > 1:
                fn = lambda: sweep_mod._run_stream_sharded(
                    None, fleet, None, None, config, names, None,
                    wspec=stack, policy_devices=dp,
                )
            else:
                fn = lambda: sweep_mod._stream_grid_jit(
                    None, fleet, None, None, stack, None, config, names, None
                )
            wall_us = _bench.time_device(fn, reps)
            entries.append(_bench.timing_entry(
                task["grid"], f"streaming_3d_dp{dp}", n, steps, cells,
                wall_us, device_count=cfg["device_count"],
                host_cpus=os.cpu_count(), fleets=f, policy_devices=dp,
            ))
            continue
        scenarios = scenario_library(
            workload.synthetic_rates(n, seed=0), num_steps=steps, seed=0
        )
        cells = f * len(names) * len(scenarios)
        if task["mode"] == "scenario_axis":
            # The long-horizon grid goes through the public ``sweep`` entry
            # point: scenario axis over the full mesh, fresh arrivals per
            # call (the donation contract), prep outside the timed region.
            fn = lambda: sweep(fleet, scenarios, return_arrays=True)
        else:
            # Fleet-axis grids: one shared scenario block broadcast across
            # F identical fleets, so million-fleet prep is O(1) host work
            # and the timed region is kernel-only.
            block = jnp.stack(
                [jnp.asarray(s.arrivals, jnp.float32) for s in scenarios]
            )  # (W, S, N)
            arrivals = jnp.array(
                jnp.broadcast_to(block, (f,) + block.shape)
            )  # (F, W, S, N), materialized
            stacked = jax.tree_util.tree_map(
                lambda x: jnp.array(jnp.broadcast_to(x, (f,) + x.shape)),
                fleet,
            )
            if task["mode"] == "replicated_1d":
                # The pre-2D fallback for a non-divisible fleet axis:
                # inputs replicated on every device, every device computes
                # the full grid.  Kept only as this baseline measurement.
                layout = sharding.replicated(sharding.grid_mesh())
                arrivals_r = jax.device_put(arrivals, layout)
                stacked_r = jax.device_put(stacked, layout)
                fn = lambda: sweep_mod._stream_grid_jit(
                    arrivals_r, stacked_r, None, None, None, None, config,
                    names, "fleet",
                )
            elif jax.device_count() > 1:
                # The donated arrivals buffer is consumed per call; the
                # rebuild (one memcpy) stays inside the timed region — the
                # real per-call cost of a donating pipeline.
                fn = lambda: sweep_mod._run_stream_sharded(
                    jnp.copy(arrivals), stacked, None, None, config, names,
                    "fleet",
                )
            else:
                fn = lambda: sweep_mod._stream_grid_jit(
                    arrivals, stacked, None, None, None, None, config, names,
                    "fleet",
                )
        wall_us = _bench.time_device(fn, reps)
        kernel = {
            "default": "streaming_2d" if cfg["device_count"] > 1 else "streaming",
            "replicated_1d": "streaming_replicated_1d",
            "scenario_axis": "streaming_2d" if cfg["device_count"] > 1 else "streaming",
        }[task["mode"]]
        entries.append(_bench.timing_entry(
            task["grid"], kernel, n, steps, cells, wall_us,
            device_count=cfg["device_count"],
            host_cpus=os.cpu_count(),
            fleets=f,
            max_rss_bytes=_bench.max_rss_bytes(),
        ))
    return {"device_count": cfg["device_count"], "entries": entries}


# -- parent side -------------------------------------------------------------


def _spawn_worker(device_count: int, tasks: list[dict]) -> dict:
    from repro.core import sharding

    env = sharding.host_device_env(device_count)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO_ROOT, "src"), REPO_ROOT]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    cfg = {"device_count": device_count, "tasks": tasks}
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.scaling_frontier", "--worker"],
        input=json.dumps(cfg), env=env, cwd=REPO_ROOT,
        capture_output=True, text=True, timeout=WORKER_TIMEOUT_S,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"scaling worker ({device_count} devices) failed:\n"
            f"{proc.stdout}\n{proc.stderr}"
        )
    for line in proc.stdout.splitlines():
        if line.startswith(SENTINEL):
            return json.loads(line[len(SENTINEL):])
    raise RuntimeError(f"no {SENTINEL} line in worker output:\n{proc.stdout}")


def _wall(entries: list[dict], grid: str, kernel_prefix: str = "streaming",
          device_count: int | None = None) -> float | None:
    for e in entries:
        if (e["grid"] == grid and e["kernel"].startswith(kernel_prefix)
                and not e["kernel"].endswith("replicated_1d")
                and (device_count is None or e["device_count"] == device_count)):
            return e["wall_us"]
    return None


def run(out_dir: str | None = None) -> list[str]:
    from benchmarks import _bench, _smoke

    smoke = _smoke.smoke()
    device_counts = (1, 2) if smoke else DEVICE_COUNTS
    max_devices = max(device_counts)
    entries: list[dict] = []
    for d in device_counts:
        payload = _spawn_worker(d, _tasks(d, max_devices, smoke))
        entries.extend(payload["entries"])

    path = _bench.write("scaling", entries, out_dir=out_dir)

    out = [f"scaling_frontier/bench,0,path={os.path.relpath(path, REPO_ROOT)}"]
    strong_1 = _wall(entries, "strong", device_count=device_counts[0])
    for d in device_counts:
        s = _wall(entries, "strong", device_count=d)
        w = _wall(entries, "weak", device_count=d)
        if s:
            out.append(
                f"scaling_frontier/strong_d{d},{s:.1f},"
                f"speedup_vs_d{device_counts[0]}={strong_1 / s:.2f}x"
            )
        if w:
            out.append(f"scaling_frontier/weak_d{d},{w:.1f},fleets_scale_with_devices")
    two_d = _wall(entries, "scenario_major", device_count=max_devices)
    one_d = next((e["wall_us"] for e in entries
                  if e["grid"] == "scenario_major"
                  and e["kernel"] == "streaming_replicated_1d"), None)
    if two_d and one_d:
        out.append(
            f"scaling_frontier/scenario_major_2d,{two_d:.1f},"
            f"speedup_vs_1d_replicated={one_d / two_d:.2f}x"
        )
    for grid in ("frontier_10k", "million_cell", "horizon_1e5"):
        wall = _wall(entries, grid)
        if wall:
            cells = next(e["cells"] for e in entries if e["grid"] == grid)
            out.append(f"scaling_frontier/{grid},{wall:.1f},cells={cells}")
    rep = next((e["wall_us"] for e in entries
                if e["grid"] == "frontier_10k"
                and e["kernel"] == "streaming_replicated_1d"), None)
    if rep and (f10k := _wall(entries, "frontier_10k")):
        out.append(
            f"scaling_frontier/frontier_10k_1d,{rep:.1f},"
            f"slowdown_vs_2d={rep / f10k:.2f}x"
        )
    synth = next((e for e in entries
                  if e["grid"] == "horizon_synth_1e6"), None)
    mat = next((e for e in entries if e["grid"] == "horizon_mat_1e6"), None)
    if synth and mat:
        out.append(
            f"scaling_frontier/horizon_synth,{synth['wall_us']:.1f},"
            f"S={synth['num_steps']};rss={synth.get('max_rss_bytes')}"
        )
        out.append(
            f"scaling_frontier/horizon_mat,{mat['wall_us']:.1f},"
            f"wall_vs_synth={mat['wall_us'] / synth['wall_us']:.2f}x;"
            f"rss={mat.get('max_rss_bytes')}"
        )
    for e in sorted((e for e in entries if e["grid"] == "block_sweep_1e5"),
                    key=lambda e: (e["kernel"], e["block_size"])):
        arm = e["kernel"].removeprefix("streaming_")
        out.append(
            f"scaling_frontier/block_{arm}_B{e['block_size']},"
            f"{e['wall_us']:.1f},compile_s={e['compile_s']:.2f}"
        )
    best_s = next((e for e in entries
                   if e["grid"] == "horizon_synth_1e6_bestB"), None)
    best_m = next((e for e in entries
                   if e["grid"] == "horizon_mat_1e6_bestB"), None)
    if best_s and synth:
        out.append(
            f"scaling_frontier/horizon_synth_bestB,{best_s['wall_us']:.1f},"
            f"B={best_s['block_size']};"
            f"speedup_vs_B1={synth['wall_us'] / best_s['wall_us']:.2f}x;"
            f"rss={best_s.get('max_rss_bytes')}"
        )
    if best_s and best_m:
        out.append(
            f"scaling_frontier/horizon_mat_bestB,{best_m['wall_us']:.1f},"
            f"B={best_m['block_size']};"
            f"mat_vs_synth={best_m['wall_us'] / best_s['wall_us']:.2f}x"
        )
    refusal = next((e for e in entries if e.get("status")), None)
    if refusal:
        out.append(
            f"scaling_frontier/widefleet_mat,0,"
            f"refused_required_gb={refusal['required_bytes'] / 1e9:.0f};"
            f"available_gb={refusal['available_bytes'] / 1e9:.0f}"
        )
    probe = next((e for e in entries
                  if e["grid"] == "widefleet_synth_probe"), None)
    if probe:
        out.append(
            f"scaling_frontier/widefleet_synth_probe,{probe['wall_us']:.1f},"
            f"n={probe['n']};us_per_step={probe['us_per_step']:.1f}"
        )
    pol = sorted((e for e in entries if e["grid"] == "policy_axis"),
                 key=lambda e: e["policy_devices"])
    if pol:
        base = pol[0]["wall_us"]
        for e in pol:
            out.append(
                f"scaling_frontier/policy_axis_dp{e['policy_devices']},"
                f"{e['wall_us']:.1f},speedup_vs_dp1={base / e['wall_us']:.2f}x"
            )
    return out


def main() -> None:
    if "--worker" in sys.argv[1:]:
        cfg = json.loads(sys.stdin.read())
        payload = _worker(cfg)
        print(SENTINEL + json.dumps(payload))
        return
    if "--smoke" in sys.argv[1:]:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
