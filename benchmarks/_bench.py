"""Stable-schema ``BENCH_*.json`` writers — the perf trajectory record.

Benchmarks that track a hot path additionally write a flat machine-diffable
file at the **repo root** (``BENCH_<name>.json``) so future PRs can compare
wall time and memory against the numbers this PR measured on the same
machine.  In smoke mode the file goes to ``experiments/smoke/`` instead —
liveness-only reduced-config numbers must never clobber the repo-root
trajectory record (the same segregation .gitignore enforces for the other
smoke artifacts); CI's bench-smoke upload covers both locations.  The
schema is deliberately boring and append-only:

    {
      "benchmark": "...",          # writer module
      "schema_version": 1,         # bump only on breaking layout changes
      "smoke": false,              # reduced CI configuration?
      "backend": "cpu",
      "device_count": 1,
      "entries": [ {flat str/number dict per measured grid}, ... ]
    }

Per-entry keys are the writer's contract; the two current writers
(``fleet_scaling``, ``sweep_grid``) emit ``kernel`` ("streaming"|"trace"),
``wall_us``, ``us_per_step``, ``us_per_step_per_cell``, ``cells``,
``num_steps``, plus ``block_size`` (the streaming time-block B the row
ran at; 1 = single-level scan) and ``compile_s`` (cold
``compile_probe`` seconds, ``None`` when not probed).  The best-effort memory probes below appear only on entries
where the reading is attributable (``fleet_scaling``'s ``memory_probe``
grid, which runs before anything heavier, and the ``frontier`` grid) —
``ru_maxrss`` is a process-wide high-water mark, so stamping it on every
timing entry would just echo the largest earlier run.  CI's bench-smoke
job uploads the smoke-mode copies per push.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax

from benchmarks import _smoke

SCHEMA_VERSION = 1
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RSS_BUDGET_ENV = "REPRO_BENCH_RSS_BUDGET_BYTES"
# Smoke configurations are liveness-sized; a writer whose smoke run grows
# past this is holding something horizon- or grid-shaped it shouldn't be.
SMOKE_RSS_BUDGET_BYTES = 4 * 1024**3


def time_device(fn, reps: int) -> float:
    """Mean wall time (us) over ``reps`` calls, after a warmup/compile call.

    ``fn`` must return device arrays (``return_arrays=True``);
    ``jax.block_until_ready`` waits for the device work itself instead of
    round-tripping through ``np.asarray`` host copies — the one timing
    methodology for every BENCH writer.
    """
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def compile_probe(jitted, *args, **kwargs) -> tuple[float, object]:
    """Cold-compile probe: ``(compile_s, compiled)`` for a jitted callable.

    Times ``jitted.lower(*args, **kwargs).compile()`` — tracing + XLA
    compilation, the one-time cost a fresh process pays for this shape —
    and returns the AOT ``Compiled`` object so the caller can time
    execution on it directly without paying (or polluting the timing
    with) a second compile.  The compiled object is called with the
    *dynamic* arguments only; statics are baked in at lowering.
    """
    t0 = time.perf_counter()
    compiled = jitted.lower(*args, **kwargs).compile()
    return time.perf_counter() - t0, compiled


def live_bytes() -> int:
    """Total bytes of currently-live device arrays (``jax.live_arrays``).

    Measured while a mode's outputs are still referenced, this is the
    resident footprint the caller pays to *hold* a result — the number that
    separates trace materialization (O(S·N) per cell) from streaming
    accumulation (O(N) per cell).
    """
    return int(sum(int(getattr(x, "nbytes", 0)) for x in jax.live_arrays()))


def peak_bytes() -> int | None:
    """Backend-reported peak allocation (``device.memory_stats``), covering
    XLA's transient scratch too; ``None`` when the backend (notably CPU)
    does not report memory stats."""
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    peak = stats.get("peak_bytes_in_use")
    return int(peak) if peak else None


def max_rss_bytes() -> int:
    """Process high-water-mark RSS (``ru_maxrss``) in bytes.

    The only peak probe that sees XLA's *transient* buffers on the CPU
    backend (``memory_stats`` is None there).  It is monotone — a high-water
    mark, never a current reading — so measure cheap modes before expensive
    ones: a mode's reading is only attributable to it when it *raises* the
    mark.
    """
    import resource

    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":  # bytes on macOS, KiB on Linux
        return int(rss)
    return int(rss) * 1024


def timing_entry(
    grid: str, kernel: str, n: int, num_steps: int, cells: int,
    wall_us: float, block_size: int = 1, compile_s: float | None = None,
    **extra,
) -> dict:
    """One timing entry in the contract schema — the single constructor
    every writer uses, so the per-entry keys cannot drift between files.

    ``block_size`` is the streaming time-block B the row ran at (1 = the
    classic single-level scan) and ``compile_s`` the cold
    ``jit(...).lower().compile()`` wall seconds from ``compile_probe``
    (``None`` when the writer did not probe — e.g. the warmup call
    compiled inline).  ``extra`` adds attributable-only fields (e.g.
    ``max_rss_bytes``)."""
    return {
        "grid": grid, "kernel": kernel, "n": n, "num_steps": num_steps,
        "cells": cells, "wall_us": wall_us,
        "us_per_step": wall_us / num_steps,
        "us_per_step_per_cell": wall_us / (num_steps * cells),
        "peak_device_bytes": peak_bytes(),
        "block_size": block_size,
        "compile_s": compile_s,
        **extra,
    }


def write(name: str, entries: list[dict], out_dir: str | None = None) -> str:
    """Write ``BENCH_<name>.json``; returns the path.

    Destination: ``out_dir`` when the caller passed an explicit one (an
    ad-hoc run redirecting its artifacts must not clobber the committed
    record), else ``experiments/smoke/`` in smoke mode (reduced-config
    numbers never overwrite the trajectory record), else the repo root.
    """
    if out_dir is None and _smoke.smoke():
        out_dir = os.path.join(REPO_ROOT, "experiments", "smoke")
    if out_dir is not None:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"BENCH_{name}.json")
    else:
        path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    payload = {
        "benchmark": name,
        "schema_version": SCHEMA_VERSION,
        "smoke": _smoke.smoke(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "entries": entries,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)
    _check_rss_budget(name)
    return path


def rss_budget_bytes() -> int | None:
    """The peak-RSS budget for this process, or ``None`` (unenforced).

    ``REPRO_BENCH_RSS_BUDGET_BYTES`` pins an explicit budget anywhere; in
    smoke mode a default budget applies — the memory regression gate for
    CI's bench-smoke job (a streaming kernel that silently re-materializes
    its horizon blows straight through it).
    """
    env = os.environ.get(RSS_BUDGET_ENV, "")
    if env:
        return int(env)
    return SMOKE_RSS_BUDGET_BYTES if _smoke.smoke() else None


def _check_rss_budget(name: str) -> None:
    """Raise if the process high-water RSS exceeds the budget.

    Runs *after* the BENCH file is written so the measurements survive for
    diagnosis — the breach fails the run, not the record.
    """
    budget = rss_budget_bytes()
    if budget is None:
        return
    rss = max_rss_bytes()
    if rss > budget:
        raise RuntimeError(
            f"BENCH_{name}: peak RSS {rss / 1e9:.2f} GB exceeds the "
            f"{budget / 1e9:.2f} GB budget ({RSS_BUDGET_ENV} overrides)"
        )


def write_index(out_dir: str | None = None) -> str:
    """Consolidate the repo-root ``BENCH_*.json`` records into
    ``BENCH_index.json`` — one line of provenance per benchmark file
    (mtime, smoke flag, device count, entry count) plus the headline
    numbers (largest ``wall_us`` entry and best ``us_per_step_per_cell``)
    so "what do we currently measure, and how fast is it" is one file
    instead of a directory scan."""
    root = REPO_ROOT if out_dir is None else out_dir
    files = sorted(
        f for f in os.listdir(root)
        if f.startswith("BENCH_") and f.endswith(".json")
        and f != "BENCH_index.json"
    )
    index = []
    for fname in files:
        path = os.path.join(root, fname)
        try:
            with open(path) as fh:
                payload = json.load(fh)
        except (OSError, json.JSONDecodeError) as exc:
            index.append({"file": fname, "error": str(exc)})
            continue
        entries = payload.get("entries", [])
        timed = [e for e in entries if isinstance(e.get("wall_us"), (int, float))]
        headline = max(timed, key=lambda e: e["wall_us"], default=None)
        per_cell = [e for e in timed
                    if isinstance(e.get("us_per_step_per_cell"), (int, float))]
        best = min(per_cell, key=lambda e: e["us_per_step_per_cell"],
                   default=None)
        compiles = [e["compile_s"] for e in entries
                    if isinstance(e.get("compile_s"), (int, float))]
        index.append({
            "file": fname,
            "benchmark": payload.get("benchmark"),
            "date": time.strftime(
                "%Y-%m-%dT%H:%M:%SZ", time.gmtime(os.path.getmtime(path))
            ),
            "smoke": payload.get("smoke"),
            "device_count": payload.get("device_count"),
            "num_entries": len(entries),
            "headline_grid": headline["grid"] if headline else None,
            "headline_wall_us": headline["wall_us"] if headline else None,
            "best_us_per_step_per_cell": (
                best["us_per_step_per_cell"] if best else None
            ),
            "max_compile_s": max(compiles) if compiles else None,
        })
    out_path = os.path.join(root, "BENCH_index.json")
    with open(out_path, "w") as fh:
        json.dump({"schema_version": SCHEMA_VERSION, "files": index}, fh,
                  indent=1)
    return out_path
