"""Integrated-stack benchmark: the paper's policies driving REAL (reduced)
models through the serving engine — tokens/s and request latency per
policy.  This is the engine-level analogue of Table II."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks import _smoke
from repro.configs import get_config
from repro.core.agents import AgentSpec, Fleet
from repro.models.model import build_model
from repro.serving.engine import AgentRuntime, FleetEngine


def _build(policy: str):
    fleet = Fleet.from_specs([
        AgentSpec("coordinator", 100.0, 100.0, 0.10, 1),
        AgentSpec("nlp", 2000.0, 50.0, 0.30, 2),
        AgentSpec("reasoning", 3000.0, 30.0, 0.35, 1),
    ])
    key = jax.random.key(0)
    archs = {"coordinator": "qwen2-vl-2b", "nlp": "granite-8b", "reasoning": "mixtral-8x7b"}
    rts = {}
    for name in fleet.names:
        cfg = get_config(archs[name], reduced=True)
        api = build_model(cfg)
        rts[name] = AgentRuntime(name, api, api.init(key), max_len=48, batch_slots=2)
    return FleetEngine(fleet, rts, policy=policy, budget_tokens=32)


def run(out_dir: str | None = None) -> list[str]:
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    res = {}
    for policy in ("adaptive", "static_equal", "round_robin"):
        eng = _build(policy)
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for t in range(_smoke.steps(12, 6)):
            eng.submit("coordinator", rng.integers(0, 100, 6), 2)
            if t % 2 == 0:
                eng.submit("nlp", rng.integers(0, 100, 6), 2)
            if t % 3 == 0:
                eng.submit("reasoning", rng.integers(0, 100, 6), 2)
            eng.step()
        wall = time.perf_counter() - t0
        m = eng.metrics()
        res[policy] = {**{k: v for k, v in m.items() if k != "per_agent_latency"},
                       "wall_s": round(wall, 2)}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "serving_engine.json"), "w") as fh:
        json.dump(res, fh, indent=1)
    a, r = res["adaptive"], res["round_robin"]
    return [
        f"engine/adaptive,0,completed={a['completed']};lat={a['avg_latency_ticks']:.1f}t",
        f"engine/round_robin,0,completed={r['completed']};lat={r['avg_latency_ticks']:.1f}t",
    ]
