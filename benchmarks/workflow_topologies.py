"""Allocation policies ranked under workflow *topologies*, not just arrival
processes — the scenario dimension the paper claims (collaborative
reasoning: coordinators fanning out to specialists) but never parameterizes.

One jitted (workflow × policy × scenario) grid over the paper's Table I
fleet: the canonical topology library (independent, coordinator_star,
pipeline_chain, hierarchical, synthetic DAG) against the standard scenario
library, every registered policy.  Reports the grid wall time, the winning
policy per topology/scenario by end-to-end critical-path latency, and how
often the winner under the independent workflow *loses* once the same
traffic flows through a topology — the routing layer's whole point.

Writes ``experiments/paper/workflow_topologies.json``.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks import _smoke
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.simulator import METRIC_NAMES
from repro.core.sweep import scenario_library, sweep_workflows, workflow_scenario_library

RANK_METRICS = ("critical_path_latency", "avg_latency", "sink_throughput")


def run(out_dir: str | None = None) -> list[str]:
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    fleet = paper_fleet()
    num_steps = _smoke.steps(100)
    workflows = workflow_scenario_library(fleet.num_agents, seed=0)
    scenarios = scenario_library(PAPER_ARRIVAL_RATES, num_steps=num_steps, seed=0)

    grid = lambda: sweep_workflows(fleet, workflows, scenarios)
    res = grid()  # warmup: compiles the whole (K, P, W) program
    t0 = time.perf_counter()
    res = grid()
    us = (time.perf_counter() - t0) * 1e6

    table = res.table()
    best = {
        m: table.best(m, minimize=(m != "sink_throughput")) for m in RANK_METRICS
    }

    # How often does routing change the verdict?  Compare each topology's
    # winner against the independent workflow's winner for the same scenario.
    flips = 0
    cells = 0
    ref = {k.split("/", 1)[1]: v for k, v in best["critical_path_latency"].items()
           if k.startswith("independent/")}
    for key, pol in best["critical_path_latency"].items():
        topo, scen = key.split("/", 1)
        if topo == "independent":
            continue
        cells += 1
        if ref.get(scen) != pol:
            flips += 1

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "workflow_topologies.json"), "w") as fh:
        json.dump(
            {
                "num_steps": num_steps,
                "workflows": list(res.workflow_names),
                "policies": list(res.policy_names),
                "scenarios": list(res.scenario_names),
                "metric_names": list(METRIC_NAMES),
                "grid_us": us,
                "best": best,
                "winner_flips_vs_independent": {"flipped": flips, "cells": cells},
                "rows": [dict(zip(table.columns, row)) for row in table.rows],
            },
            fh, indent=1,
        )

    k, p, w = len(res.workflow_names), len(res.policy_names), len(res.scenario_names)
    out = [f"workflows/grid,{us:.1f},cells={k * p * w}"]
    for topo in res.workflow_names:
        wins = [v for key, v in best["critical_path_latency"].items()
                if key.startswith(f"{topo}/")]
        top = max(set(wins), key=wins.count) if wins else "n/a"
        out.append(f"workflows/best_{topo},0,critpath_winner={top}")
    out.append(
        f"workflows/verdict_flips,0,{flips}/{cells}_cells_change_winner_vs_independent"
    )
    return out
