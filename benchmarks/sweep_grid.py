"""Full policy × scenario sweep: every registered policy against the
standard 8-scenario library in one vmapped/jitted call.

Reports the wall time of the whole grid for both kernels — the streaming
default (O(P) policy dispatch, carry-accumulated metrics) and the
trace-materializing oracle — and the winning policy per scenario by average
latency, the scaled-up version of the paper's Table II comparison.  Timing
blocks on the jitted device output (``jax.block_until_ready`` via
``return_arrays=True``), so the numbers measure device work rather than
dispatch + host copy.

Writes ``experiments/paper/sweep_grid.json`` and the stable-schema
``BENCH_sweep.json`` at the repo root (see ``benchmarks/_bench.py``)."""
from __future__ import annotations

import json
import os

from benchmarks import _bench, _smoke
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.sweep import scenario_library, sweep

REPS = 20


def run(out_dir: str | None = None) -> list[str]:
    bench_dir = out_dir  # explicit destination redirects BENCH files too
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    fleet = paper_fleet()
    num_steps = _smoke.steps(100)
    scenarios = scenario_library(PAPER_ARRIVAL_RATES, num_steps=num_steps, seed=0)
    reps = _smoke.reps(REPS, 2)
    wall = {}
    for kernel, fn in (
        ("streaming", lambda: sweep(fleet, scenarios, return_arrays=True)),
        ("trace",
         lambda: sweep(fleet, scenarios, stream=False, return_arrays=True)),
    ):
        wall[kernel] = _bench.time_device(fn, reps)
    res = sweep(fleet, scenarios)
    cells = len(res.policy_names) * len(res.scenario_names)

    table = res.table()
    best = table.best("avg_latency")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "sweep_grid.json"), "w") as fh:
        json.dump(
            {
                "policies": list(res.policy_names),
                "scenarios": list(res.scenario_names),
                "grid_us": wall["streaming"],
                "trace_grid_us": wall["trace"],
                "stream_speedup": wall["trace"] / wall["streaming"],
                "best_by_avg_latency": best,
                "rows": [dict(zip(table.columns, row)) for row in table.rows],
            },
            fh, indent=1,
        )
    _bench.write("sweep", [
        _bench.timing_entry(
            "paper_fleet", kernel, fleet.num_agents, num_steps, cells, us
        )
        for kernel, us in wall.items()
    ], out_dir=bench_dir)

    out = [
        f"sweep/grid,{wall['streaming']:.1f},cells={cells}",
        f"sweep/grid_trace,{wall['trace']:.1f},speedup={wall['trace'] / wall['streaming']:.2f}x",
    ]
    for scen, pol in best.items():
        lat = res.summary(pol, scen).avg_latency
        out.append(f"sweep/best_{scen},0,policy={pol};lat={lat:.1f}")
    return out
