"""Full policy × scenario sweep: every registered policy against the
standard 8-scenario library in one vmapped/jitted call.

Reports the wall time of the whole grid (compile excluded) and the winning
policy per scenario by average latency — the scaled-up version of the
paper's Table II comparison."""
from __future__ import annotations

import json
import os
import time

from benchmarks import _smoke
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.sweep import scenario_library, sweep


def run(out_dir: str | None = None) -> list[str]:
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    fleet = paper_fleet()
    scenarios = scenario_library(PAPER_ARRIVAL_RATES, num_steps=_smoke.steps(100), seed=0)
    res = sweep(fleet, scenarios)  # warmup: compiles the grid
    t0 = time.perf_counter()
    res = sweep(fleet, scenarios)
    us = (time.perf_counter() - t0) * 1e6

    table = res.table()
    best = table.best("avg_latency")
    cells = len(res.policy_names) * len(res.scenario_names)

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "sweep_grid.json"), "w") as fh:
        json.dump(
            {
                "policies": list(res.policy_names),
                "scenarios": list(res.scenario_names),
                "best_by_avg_latency": best,
                "rows": [dict(zip(table.columns, row)) for row in table.rows],
            },
            fh, indent=1,
        )

    out = [f"sweep/grid,{us:.1f},cells={cells}"]
    for scen, pol in best.items():
        lat = res.summary(pol, scen).avg_latency
        out.append(f"sweep/best_{scen},0,policy={pol};lat={lat:.1f}")
    return out
