"""Shared benchmark knobs.

``REPRO_BENCH_SMOKE=1`` (or ``benchmarks.run --smoke``) switches every
benchmark to a reduced configuration — fewer simulated steps, fewer timing
reps, smaller fleets — so CI can execute the *entire* driver end-to-end on
every push (artifacts included) without paying full-benchmark wall time.
Numbers produced in smoke mode are for liveness, not for the paper tables.
"""
from __future__ import annotations

import os


def smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def steps(full: int, reduced: int | None = None) -> int:
    """Simulated-step count: ``full`` normally, ``reduced`` (default
    full//5, floor 10) in smoke mode."""
    if not smoke():
        return full
    return reduced if reduced is not None else max(full // 5, 10)


def reps(full: int, reduced: int = 1) -> int:
    """Timing-loop repetitions: ``full`` normally, ``reduced`` in smoke."""
    return reduced if smoke() else full


def sizes(full: tuple, keep: int = 3) -> tuple:
    """Size-scaling benchmarks keep only the ``keep`` smallest sizes in
    smoke mode; the one truncation policy for every scaling curve."""
    return full[:keep] if smoke() else full


def out_dir(default: str = "experiments/paper") -> str:
    """Artifact directory: liveness-only smoke numbers must never land in
    the checked-in paper artifacts, whichever entry point ran the module."""
    return "experiments/smoke" if smoke() else default
