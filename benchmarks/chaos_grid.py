"""Chaos grid: every registered policy ranked under failure injection.

One streaming sweep call with a stacked failure axis evaluates the full
policy registry against a (revocation rate x deadline tightness) grid of
``FailureSpec`` rows — including the all-off baseline — in a single
vmapped kernel.  Two robustness rankings come out of it:

- **availability**: a cell's throughput relative to the same
  policy/scenario under the no-failure baseline row (how much service a
  policy preserves when instances are revoked mid-flight);
- **SLO attainment**: served mass as a fraction of served + deadline
  drops + deadline violations (how much of the traffic a policy lands
  inside its latency budget).

The point of the benchmark is that these rankings *disagree* with the
mean-latency ranking: a policy that wins on average latency in calm seas
can shed exactly the wrong queues once deadlines bite.  Each
(failure x scenario) cell records its SLO-attainment winner next to its
avg-latency winner and the summary counts the differing cells.

Writes ``experiments/paper/chaos_grid.json`` and the stable-schema
``BENCH_chaos.json`` at the repo root (see ``benchmarks/_bench.py``)."""
from __future__ import annotations

import json
import os
import sys

import numpy as np

from benchmarks import _bench, _smoke
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.failures import failure_spec
from repro.core.sweep import Scenario, sweep
from repro.core import workload

REPS = 10
_EPS = 1e-9

# The chaos grid's two axes.  Revocation is an MMPP burst process (enter
# probability per step; exit 0.35, 60% of the warm pool gone while in the
# burst); the deadline axis tightens the per-request drain-time budget
# with a single retry before mass is dropped.
REVOCATION_RATES = (0.0, 0.08, 0.25)
DEADLINES_S = (0.0, 8.0, 2.0)


def _cell_name(rev: float, dl: float) -> str:
    if rev == 0.0 and dl == 0.0:
        return "none"
    return f"rev{rev:g}_dl{dl:g}"


def failure_grid() -> tuple:
    """The (revocation x deadline) FailureSpec rows, baseline first."""
    specs = []
    for rev in REVOCATION_RATES:
        for dl in DEADLINES_S:
            specs.append(failure_spec(
                _cell_name(rev, dl),
                revoke_p_enter=rev,
                revoke_p_exit=0.35,
                revoke_frac=0.6 if rev > 0.0 else 0.0,
                deadline_s=dl,
                retry_budget=1 if dl > 0.0 else 0,
                seed=7,
            ))
    return tuple(specs)


def run(out_dir: str | None = None) -> list[str]:
    bench_dir = out_dir  # explicit destination redirects BENCH files too
    out_dir = _smoke.out_dir() if out_dir is None else out_dir
    fleet = paper_fleet()
    num_steps = _smoke.steps(100)
    scenarios = (
        Scenario("constant", workload.constant(PAPER_ARRIVAL_RATES, num_steps)),
        Scenario("overload_3x",
                 workload.scaled(PAPER_ARRIVAL_RATES, num_steps, 3.0)),
    )
    specs = failure_grid()

    reps = _smoke.reps(REPS, 2)
    wall = _bench.time_device(
        lambda: sweep(fleet, scenarios, failures=specs, return_arrays=True),
        reps,
    )
    res = sweep(fleet, scenarios, failures=specs)
    assert res.failure_names is not None
    base = res.failure_names.index("none")

    thr = res.metric("total_throughput")       # (B, P, W)
    dropped = res.metric("dropped")
    viol = res.metric("slo_violations")
    lat = res.metric("avg_latency")
    availability = thr / (thr[base][None] + _EPS)
    slo_attainment = thr / (thr + dropped + viol + _EPS)

    cells = []
    differing = 0
    for b, fname in enumerate(res.failure_names):
        for w, scen in enumerate(res.scenario_names):
            slo_w = int(np.argmax(slo_attainment[b, :, w]))
            lat_w = int(np.argmin(lat[b, :, w]))
            differs = slo_w != lat_w
            differing += differs
            cells.append({
                "failure": fname,
                "scenario": scen,
                "slo_winner": res.policy_names[slo_w],
                "slo_attainment": round(float(slo_attainment[b, slo_w, w]), 4),
                "latency_winner": res.policy_names[lat_w],
                "winner_latency": round(float(lat[b, lat_w, w]), 2),
                "winners_differ": bool(differs),
                "availability": {
                    pol: round(float(availability[b, p, w]), 4)
                    for p, pol in enumerate(res.policy_names)
                },
            })

    n_cells = len(res.failure_names) * len(res.policy_names) * len(
        res.scenario_names)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "chaos_grid.json"), "w") as fh:
        json.dump(
            {
                "policies": list(res.policy_names),
                "scenarios": list(res.scenario_names),
                "failures": list(res.failure_names),
                "revocation_rates": list(REVOCATION_RATES),
                "deadlines_s": list(DEADLINES_S),
                "grid_us": wall,
                "differing_winner_cells": int(differing),
                "cells": cells,
            },
            fh, indent=1,
        )
    _bench.write("chaos", [
        _bench.timing_entry(
            "chaos_grid", "streaming", fleet.num_agents, num_steps,
            n_cells, wall,
            failure_cells=len(res.failure_names),
            differing_winner_cells=int(differing),
        )
    ], out_dir=bench_dir)

    worst = min(
        (c for c in cells if c["failure"] != "none"),
        key=lambda c: c["availability"][c["slo_winner"]],
    )
    return [
        f"chaos/grid,{wall:.1f},cells={n_cells}",
        f"chaos/differing_winners,0,cells={differing}/{len(cells)}",
        (
            f"chaos/worst_cell,0,failure={worst['failure']};"
            f"scenario={worst['scenario']};slo_winner={worst['slo_winner']};"
            f"attainment={worst['slo_attainment']}"
        ),
    ]


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
