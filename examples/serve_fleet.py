"""End-to-end driver (the paper is a serving paper): the Table I fleet of
four agents, each a REAL reduced-config model from the assigned pool,
served with batched requests under the adaptive allocator — then the same
traffic under round-robin for comparison.

  PYTHONPATH=src python examples/serve_fleet.py [--ticks 16]
"""
import argparse
import json

import numpy as np

from repro.launch.serve import DEFAULT_FLEET, build_engine


def drive(policy: str, ticks: int, seed: int = 0):
    eng = build_engine(policy, budget_tokens=48, max_len=48)
    rng = np.random.default_rng(seed)
    for t in range(ticks):
        for (name, _, _, _, _, rate) in DEFAULT_FLEET:
            for _ in range(rng.poisson(rate)):
                eng.submit(name, rng.integers(0, 1000, 6), max_new_tokens=3)
        eng.step()
    return eng.metrics()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=16)
    args = ap.parse_args()
    results = {}
    for policy in ("adaptive", "round_robin"):
        m = drive(policy, args.ticks)
        results[policy] = m
        print(f"\n== {policy} ==")
        print(json.dumps(m, indent=1))
    a, r = results["adaptive"], results["round_robin"]
    if np.isfinite(a["avg_latency_ticks"]) and np.isfinite(r["avg_latency_ticks"]):
        red = 1 - (a["avg_latency_ticks"] + 1) / (r["avg_latency_ticks"] + 1)
        print(f"\nadaptive vs round-robin latency reduction: {100*red:.0f}% "
              f"(paper's simulator-level figure: 85%)")


if __name__ == "__main__":
    main()
