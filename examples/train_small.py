"""Train a small model end-to-end on the synthetic pipeline with the
from-scratch AdamW + cosine schedule, then checkpoint and restore.

  PYTHONPATH=src python examples/train_small.py [--steps 60]

(The paper's kind is serving, so the flagship end-to-end driver is
serve_fleet.py; this exercises the full training substrate: data ->
train_step -> optimizer -> checkpoint -> restore -> eval.)
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.model import build_model
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_step import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    api = build_model(cfg)
    params = api.init(jax.random.key(0), dtype=jnp.float32)
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps)
    opt_state = init_opt_state(params)
    step = jax.jit(build_train_step(api, opt_cfg))
    data = SyntheticTokens(DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0))

    first = last = None
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 10 == 0:
            print(f"step {i:4d} loss={loss:.4f}", flush=True)
    print(f"loss {first:.3f} -> {last:.3f} in {args.steps} steps "
          f"({(time.time()-t0)/args.steps:.2f}s/step)")
    assert last < first, "training must reduce loss"

    path = os.path.join(tempfile.mkdtemp(), "ck.npz")
    ckpt.save(path, {"params": params})
    restored = ckpt.restore(path, {"params": params})["params"]
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    l1, _ = api.train_loss(params, batch)
    l2, _ = api.train_loss(restored, batch)
    print(f"checkpoint roundtrip: loss {float(l1):.6f} == {float(l2):.6f}")
    assert float(l1) == float(l2)


if __name__ == "__main__":
    main()
