"""§V-B robustness study + beyond-paper policy comparison under four
workload regimes (steady / 3x overload / spike / diurnal).

  PYTHONPATH=src python examples/robustness_study.py
"""
import jax
import jax.numpy as jnp

from repro.core import PAPER_ARRIVAL_RATES, paper_fleet, run_policy, workload

fleet = paper_fleet()
rates = jnp.asarray(PAPER_ARRIVAL_RATES)

REGIMES = {
    "steady": workload.constant(rates, 100),
    "overload_3x": workload.scaled(rates, 100, 3.0),
    "spike_10x": workload.spike(rates, 100, spike_agent=3, spike_start=50, spike_len=20),
    "diurnal": workload.diurnal(rates, 100),
    "poisson": workload.poisson(rates, 100, jax.random.key(0)),
}
POLICIES = ("static_equal", "round_robin", "adaptive", "water_filling", "predictive")

print(f"{'regime':12s} " + " ".join(f"{p:>14s}" for p in POLICIES) + "   (avg latency s)")
for regime, arr in REGIMES.items():
    lats = [run_policy(p, arr, fleet).avg_latency for p in POLICIES]
    print(f"{regime:12s} " + " ".join(f"{l:14.1f}" for l in lats))

print("\nthroughput (rps):")
for regime, arr in REGIMES.items():
    tps = [run_policy(p, arr, fleet).total_throughput for p in POLICIES]
    print(f"{regime:12s} " + " ".join(f"{t:14.2f}" for t in tps))
