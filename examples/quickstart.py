"""Quickstart: reproduce the paper's Table II in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import PAPER_ARRIVAL_RATES, paper_fleet, run_policy, workload

fleet = paper_fleet()
arrivals = workload.constant(jnp.asarray(PAPER_ARRIVAL_RATES), num_steps=100)

print(f"{'policy':16s} {'avg lat (s)':>12s} {'tput (rps)':>11s} {'cost':>7s}")
for policy in ("static_equal", "round_robin", "adaptive"):
    s = run_policy(policy, arrivals, fleet)
    print(f"{policy:16s} {s.avg_latency:12.1f} {s.total_throughput:11.2f} ${s.cost:.3f}")

print("\npaper Table II:  static 110.3 / 60.0   round-robin 756.1 / 60.0"
      "   adaptive 111.9 / 58.1   (all $0.020)")
