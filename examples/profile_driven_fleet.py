"""Profile-driven fleet: Table I derived from MEASURED dry-run artifacts
instead of hand-picked constants, then simulated under all policies.

This is the paper's §V-C "agent profiling methodology" made concrete: the
allocator consumes (T_i, R_i, M_i) that come from the roofline of each
assigned architecture's decode step on the production mesh.

  PYTHONPATH=src python examples/profile_driven_fleet.py
"""
import jax.numpy as jnp

from repro.core import run_policy, workload
from repro.core.profiles import fleet_from_archs, profile_arch

ARCH_PRIORITY = {           # coordinator-class small models high priority
    "qwen2-vl-2b": 1,
    "granite-8b": 2,
    "mixtral-8x7b": 2,
    "llama3-405b": 1,
}

print("derived profiles (from experiments/roofline + experiments/dryrun):")
for arch in ARCH_PRIORITY:
    p = profile_arch(arch)
    if p is None:
        raise SystemExit("run `python -m repro.launch.roofline --arch all --shape decode_32k` first")
    print(f"  {arch:16s} T={p['throughput_tokens_per_s']:10.0f} tok/s  "
          f"R={p['min_gpu']:.3f}  M={p['model_mb']:.0f}MB  bottleneck={p['bottleneck']}")

fleet = fleet_from_archs(ARCH_PRIORITY)
# offered load proportional to capability, 3x oversubscribed overall
rates = jnp.asarray([t * 0.75 for t in fleet.base_throughput])
arr = workload.constant(rates, 100)

print(f"\n{'policy':16s} {'avg lat (s)':>12s} {'tput (tok/s)':>13s}")
for policy in ("static_equal", "round_robin", "adaptive", "water_filling"):
    s = run_policy(policy, arr, fleet)
    print(f"{policy:16s} {s.avg_latency:12.2f} {s.total_throughput:13.0f}")
