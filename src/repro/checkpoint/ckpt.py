"""Flat-npz checkpointing for arbitrary pytrees (params + optimizer state).

Keys are tree paths; bfloat16 leaves are stored as uint16 views with a
dtype sidecar so numpy round-trips exactly.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(path: str, tree) -> None:
    flat = _flatten(tree)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        arr = np.asarray(v)
        dtypes[k] = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[k] = arr
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, __dtypes__=json.dumps(dtypes), **arrays)


def restore(path: str, like):
    """Restore into the structure of `like` (a pytree of arrays/SDS)."""
    with np.load(path, allow_pickle=False) as z:
        dtypes = json.loads(str(z["__dtypes__"]))
        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for pathk, leaf in flat_like[0]:
            k = jax.tree_util.keystr(pathk)
            arr = z[k]
            if dtypes[k] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{k}: checkpoint {arr.shape} vs model {leaf.shape}")
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)
