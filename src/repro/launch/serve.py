"""Serving launcher: the paper's multi-agent fleet on real models.

  PYTHONPATH=src python -m repro.launch.serve --policy adaptive --ticks 20
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core.agents import AgentSpec, Fleet
from repro.models.model import build_model
from repro.serving.engine import AgentRuntime, FleetEngine

# Paper Table I fleet -> backbone per agent (reduced variants on CPU).
DEFAULT_FLEET = (
    ("coordinator", "qwen2-vl-2b", 100.0, 0.10, 1, 3),
    ("specialist_nlp", "granite-8b", 50.0, 0.30, 2, 2),
    ("specialist_vision", "qwen2-vl-2b", 60.0, 0.25, 2, 2),
    ("specialist_reasoning", "mixtral-8x7b", 30.0, 0.35, 1, 1),
)


def build_engine(policy: str, *, reduced: bool = True, budget_tokens: int = 64,
                 max_len: int = 64, batch_slots: int = 4) -> FleetEngine:
    specs, rts = [], {}
    key = jax.random.key(0)
    for name, arch, tput, min_gpu, pri, _rate in DEFAULT_FLEET:
        cfg = get_config(arch, reduced=reduced)
        api = build_model(cfg)
        specs.append(AgentSpec(name, cfg.param_count / 1e6, tput, min_gpu, pri))
        rts[name] = AgentRuntime(name, api, api.init(key), max_len=max_len,
                                 batch_slots=batch_slots)
    return FleetEngine(Fleet.from_specs(specs), rts, policy=policy,
                       budget_tokens=budget_tokens)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="adaptive")
    ap.add_argument("--ticks", type=int, default=20)
    ap.add_argument("--budget-tokens", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    eng = build_engine(args.policy, budget_tokens=args.budget_tokens)
    rng = np.random.default_rng(args.seed)
    for t in range(args.ticks):
        for (name, _, _, _, _, rate) in DEFAULT_FLEET:
            for _ in range(rng.poisson(rate)):
                eng.submit(name, rng.integers(0, 1000, args.prompt_len), args.max_new)
        eng.step()
        h = eng.history[-1]
        print(f"tick {t:3d} alloc={[round(x,2) for x in h['allocation']]} "
              f"queues={[int(q) for q in h['queues']]}", flush=True)
    print(json.dumps(eng.metrics(), indent=1))


if __name__ == "__main__":
    main()
