import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, with NO array allocation (ShapeDtypeStruct inputs).

For each pair we lower the step the shape dictates (train_step / prefill /
decode_step), compile under SPMD, and record:
  * memory_analysis()  — proves the per-device working set fits HBM,
  * cost_analysis()    — per-device HLO FLOPs / bytes for §Roofline,
  * collective bytes   — parsed from the post-SPMD HLO text by op kind.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
      --shape decode_32k --multi-pod --rules serve_v2
"""
import argparse
import json
import re
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import HW, make_production_mesh
from repro.models.model import (
    INPUT_SHAPES,
    build_model,
    decode_token_specs,
    input_specs,
    shape_applicable,
)
from repro.models.params import abstract_params
from repro.training.optimizer import OptimizerConfig, abstract_opt_state
from repro.training.train_step import build_train_step

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all `dtype[dims]` shapes in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved through each collective kind (output sizes)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w-]+)\(", line)
        if not m:
            continue
        typestr, opname = m.groups()
        base = opname.rstrip(".0123456789")
        # normalize e.g. all-gather-start / all-reduce-done
        for kind in _COLLECTIVES:
            if base == kind or base.startswith(kind + "-"):
                if base.endswith("-done"):
                    break  # counted at -start
                out[kind] += _shape_bytes(typestr)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _lowerable(arch: str, shape_name: str, mesh, rules_name: str = "serve",
               moe_impl: str = None):
    """Build (fn, args, in_shardings) for one (arch, shape) pair."""
    import dataclasses as _dc

    cfg = get_config(arch)
    if moe_impl:
        cfg = _dc.replace(cfg, moe_impl=moe_impl)
    shape = INPUT_SHAPES[shape_name]
    api = build_model(cfg)
    rules = shd.RULE_SETS[rules_name]

    params_sds = api.abstract()
    params_sh = shd.shardings_for_decls(mesh, api.param_decls, rules)

    if shape.mode == "train":
        opt_cfg = OptimizerConfig()
        step_fn = build_train_step(api, opt_cfg)
        opt_sds = abstract_opt_state(params_sds)
        opt_sh = {
            "m": params_sh,
            "v": params_sh,
            "step": shd.replicated(mesh),
        }
        batch_sds = input_specs(cfg, shape)
        batch_sh = shd.batch_shardings(mesh, batch_sds, rules)
        fn = jax.jit(
            step_fn,
            in_shardings=(params_sh, opt_sh, batch_sh),
            donate_argnums=(0, 1),
        )
        return fn, (params_sds, opt_sds, batch_sds)

    if shape.mode == "prefill":
        batch_sds = input_specs(cfg, shape)
        batch_sh = shd.batch_shardings(mesh, batch_sds, rules)
        fn = jax.jit(
            lambda p, b: api.prefill(p, b, shape.seq_len),
            in_shardings=(params_sh, batch_sh),
        )
        return fn, (params_sds, batch_sds)

    # decode: one new token against a seq_len cache
    cache_decl = api.cache_decls(shape.global_batch, shape.seq_len)
    cache_sds = abstract_params(cache_decl)
    cache_sh = shd.shardings_for_decls(mesh, cache_decl, rules)
    token_sds, pos_sds = decode_token_specs(shape)
    tok_sh = shd.batch_shardings(mesh, {"t": token_sds}, rules)["t"]
    fn = jax.jit(
        lambda p, c, t, pos: api.decode_step(p, c, t, pos, shape.seq_len),
        in_shardings=(params_sh, cache_sh, tok_sh, shd.replicated(mesh)),
        donate_argnums=(1,),
    )
    return fn, (params_sds, cache_sds, token_sds, pos_sds)


def run_one(arch: str, shape_name: str, *, multi_pod: bool, rules: str = None,
            moe_impl: str = None) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or ("train" if shape.mode == "train" else "serve")
    t0 = time.time()
    fn, args = _lowerable(arch, shape_name, mesh, rules, moe_impl)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    n_chips = mesh.size
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    res = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "mode": shape.mode,
        "rules": rules,
        "chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            "flops": flops,
            "bytes_accessed": bytes_acc,
            "collective_bytes": coll,
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline_s": {
            "compute": flops / HW["peak_flops_bf16"],
            "memory": bytes_acc / HW["hbm_bw"],
            "collective": coll["total"] / HW["ici_bw"],
        },
        "model_params": cfg.param_count,
        "active_params": cfg.active_param_count,
    }
    terms = res["roofline_s"]
    res["bottleneck"] = max(terms, key=terms.get)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--moe-impl", default=None, choices=[None, "einsum", "grouped"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = tuple(INPUT_SHAPES) if args.shape == "all" else (args.shape,)
    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}_{'pod2' if args.multi_pod else 'pod1'}"
            if args.rules:
                tag += f"_{args.rules}"
            if args.moe_impl:
                tag += f"_{args.moe_impl}"
            try:
                res = run_one(arch, shape, multi_pod=args.multi_pod, rules=args.rules,
                              moe_impl=args.moe_impl)
            except Exception as e:  # a failure here is a bug in our sharding
                res = {"arch": arch, "shape": shape, "error": repr(e)[:2000]}
                print(f"FAIL {tag}: {repr(e)[:300]}")
            path = os.path.join(args.out, tag + ".json")
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            if "error" not in res and "skipped" not in res:
                r = res["roofline_s"]
                print(
                    f"OK {tag}: compile={res['compile_s']}s "
                    f"compute={r['compute']:.4f}s memory={r['memory']:.4f}s "
                    f"coll={r['collective']:.4f}s bottleneck={res['bottleneck']}"
                )
            elif "skipped" in res:
                print(f"SKIP {tag}: {res['skipped']}")


if __name__ == "__main__":
    main()
