import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Scan-aware roofline accounting (§Roofline).

``compiled.cost_analysis()`` counts a ``while`` (lax.scan) body ONCE, so a
whole-step analysis of a 126-layer scanned model under-reports FLOPs/bytes
by ~L.  This script therefore compiles per-component *units* under the same
mesh/shardings and scales them by their trip counts:

  train:    grad(checkpoint(superblock)) x n_super  +  head(+grad)  +  adamw
  prefill:  superblock x n_super  +  head
  decode:   superblock_decode x n_super  +  head(S=1)

Each unit's HLO is parsed for collective bytes the same way as the full
step.  Known residual undercount: the SSD inter-chunk recurrence (a tiny
lax.scan inside the block) is still counted once per block — its FLOPs are
O(S*P*N/Q) vs the block's O(S*Q*(P+N)), <2% for our chunk sizes.

Writes experiments/roofline/<arch>_<shape>_<mesh>.json; table assembly and
MODEL_FLOPS ratios live in benchmarks/roofline.py.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as shd
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import HW, make_production_mesh
from repro.models import encdec, transformer
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.model import INPUT_SHAPES, build_model, shape_applicable
from repro.models.params import abstract_params
from repro.training.optimizer import OptimizerConfig, abstract_opt_state, adamw_update


def _cost(compiled) -> dict:
    c = compiled.cost_analysis()
    return {
        "flops": float(c.get("flops", 0.0)),
        "bytes": float(c.get("bytes accessed", 0.0)),
        "coll": collective_bytes(compiled.as_text())["total"],
    }


def _scaled(unit: dict, k: float) -> dict:
    return {kk: v * k for kk, v in unit.items()}


def _add(*units) -> dict:
    out = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    for u in units:
        for k in out:
            out[k] += u[k]
    return out


def _compile(fn, args, shardings, mesh):
    with mesh:
        return jax.jit(fn, in_shardings=shardings).lower(*args).compile()


def _batch_sh(mesh, sds, rules):
    return shd.batch_shardings(mesh, {"x": sds}, rules)["x"]


# ---------------------------------------------------------------------------
# Units for decoder-only models
# ---------------------------------------------------------------------------

def _dec_units(cfg: ModelConfig, mode: str, b: int, s: int, mesh, rules) -> dict:
    """Returns dict of unit costs + multipliers for a decoder-only model."""
    api = build_model(cfg)
    n_super, rem = transformer.super_counts(cfg)
    pat = transformer.block_pattern(cfg)
    sb_decls = transformer._superblock_decls(cfg)
    sb_sds = abstract_params(sb_decls)
    sb_sh = shd.shardings_for_decls(mesh, sb_decls, rules)
    x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    x_sh = _batch_sh(mesh, x_sds, rules)

    def sb_fwd(x, sp):
        base = jnp.arange(x.shape[1], dtype=jnp.int32)
        if cfg.mrope:
            pos = jnp.broadcast_to(base[None, :, None], (*x.shape[:2], 3))
        else:
            pos = jnp.broadcast_to(base[None], x.shape[:2])
        y, aux, _ = transformer._superblock_fwd(x, sp, cfg, pos, False)
        return y, aux

    units = {}
    if mode == "train":
        def loss_fn(x, sp):
            y, aux = jax.checkpoint(sb_fwd)(x, sp)
            return y.astype(jnp.float32).sum() + aux

        grad_fn = jax.grad(loss_fn, argnums=(0, 1))
        units["block"] = (
            _cost(_compile(grad_fn, (x_sds, sb_sds), (x_sh, sb_sh), mesh)),
            n_super + rem / max(len(pat), 1),
        )
    elif mode in ("prefill", "decode_block_ctx"):
        units["block"] = (
            _cost(_compile(sb_fwd, (x_sds, sb_sds), (x_sh, sb_sh), mesh)),
            n_super + rem / max(len(pat), 1),
        )

    return units


def _head_unit(cfg: ModelConfig, mode: str, b: int, s: int, mesh, rules):
    decls = {
        "embed": L.embed_decls(cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings),
        "final_norm": L.rmsnorm_decls(cfg.d_model),
    }
    sds = abstract_params(decls)
    sh = shd.shardings_for_decls(mesh, decls, rules)
    tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tok_sh = _batch_sh(mesh, tok_sds, rules)

    def head(p, tokens, labels):
        x = L.embed(tokens, p["embed"])
        x = L.rms_norm(x, p["final_norm"], cfg.norm_eps)
        logits = L.unembed(x, p["embed"])
        return L.cross_entropy_loss(logits, labels, cfg.padded_vocab)

    if mode == "train":
        fn = jax.grad(head, argnums=0)
    else:
        fn = lambda p, tokens, labels: head(p, tokens, labels)
    compiled = _compile(fn, (sds, tok_sds, tok_sds), (sh, tok_sh, tok_sh), mesh)
    return _cost(compiled)


def _opt_unit(cfg: ModelConfig, api, mesh, rules):
    p_sds = api.abstract()
    p_sh = shd.shardings_for_decls(mesh, api.param_decls, rules)
    o_sds = abstract_opt_state(p_sds)
    o_sh = {"m": p_sh, "v": p_sh, "step": shd.replicated(mesh)}
    ocfg = OptimizerConfig()

    def opt(grads, state, params):
        return adamw_update(grads, state, params, ocfg)

    compiled = _compile(opt, (p_sds, o_sds, p_sds), (p_sh, o_sh, p_sh), mesh)
    return _cost(compiled)


def _decode_units(cfg: ModelConfig, b: int, seq_len: int, mesh, rules):
    api = build_model(cfg)
    n_super, rem = transformer.super_counts(cfg)
    pat = transformer.block_pattern(cfg)
    spec = transformer.cache_spec(cfg, seq_len)
    sb_decls = transformer._superblock_decls(cfg)
    sb_sds = abstract_params(sb_decls)
    sb_sh = shd.shardings_for_decls(mesh, sb_decls, rules)
    cache_decls = {
        f"b{i}_{k}": transformer._block_cache_decls(k, cfg, b, spec)
        for i, k in enumerate(pat)
    }
    c_sds = abstract_params(cache_decls)
    c_sh = shd.shardings_for_decls(mesh, cache_decls, rules)
    x_sds = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
    x_sh = _batch_sh(mesh, x_sds, rules)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def sb_dec(x, sp, caches, pos):
        new = {}
        for name in sp:
            kind = name.split("_", 1)[1]
            x, nc = transformer._block_decode(kind, x, caches[name], sp[name], cfg, pos, spec)
            new[name] = nc
        return x, new

    compiled = _compile(
        sb_dec, (x_sds, sb_sds, c_sds, pos_sds),
        (x_sh, sb_sh, c_sh, shd.replicated(mesh)), mesh,
    )
    return {"block": (_cost(compiled), n_super + rem / max(len(pat), 1))}


# ---------------------------------------------------------------------------
# Units for encoder-decoder
# ---------------------------------------------------------------------------

def _encdec_units(cfg: ModelConfig, mode: str, b: int, s: int, mesh, rules, enc_len: int):
    enc_decls = encdec._enc_block_decls(cfg)
    dec_decls = encdec._dec_block_decls(cfg)
    units = {}
    for tag, decls, ss in (("enc_block", enc_decls, enc_len if mode != "train" else s),
                           ("dec_block", dec_decls, s)):
        sds = abstract_params(decls)
        sh = shd.shardings_for_decls(mesh, decls, rules)
        x_sds = jax.ShapeDtypeStruct((b, ss, cfg.d_model), jnp.bfloat16)
        x_sh = _batch_sh(mesh, x_sds, rules)

        if tag == "enc_block":
            def fwd(x, p):
                from repro.models import attention as attn
                pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
                h, _ = attn.self_attention(L.rms_norm(x, p["ln1"], cfg.norm_eps), p["attn"], cfg, pos, causal=False)
                x = x + h
                return x + L.ffn(L.rms_norm(x, p["ln2"], cfg.norm_eps), p["mlp"], cfg.ffn_type)
            args, shs, mult = (x_sds, sds), (x_sh, sh), cfg.encoder_layers
        else:
            enc_sds = jax.ShapeDtypeStruct((b, enc_len if mode != "train" else s, cfg.d_model), jnp.bfloat16)
            enc_sh = _batch_sh(mesh, enc_sds, rules)

            def fwd(x, enc_out, p):
                from repro.models import attention as attn
                pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
                h, _ = attn.self_attention(L.rms_norm(x, p["ln1"], cfg.norm_eps), p["self_attn"], cfg, pos, causal=True)
                x = x + h
                ckv = attn.cross_kv(enc_out, p["cross_attn"], cfg)
                x = x + attn.cross_attention(L.rms_norm(x, p["ln_x"], cfg.norm_eps), ckv, p["cross_attn"], cfg)
                return x + L.ffn(L.rms_norm(x, p["ln2"], cfg.norm_eps), p["mlp"], cfg.ffn_type)
            args, shs, mult = (x_sds, enc_sds, sds), (x_sh, enc_sh, sh), cfg.num_layers

        if mode == "train":
            f = fwd
            def loss_fn(*a, _f=f):
                return jax.checkpoint(_f)(*a).astype(jnp.float32).sum()
            fn = jax.grad(loss_fn, argnums=tuple(range(len(args))))
        else:
            fn = fwd
        units[tag] = (_cost(_compile(fn, args, shs, mesh)), mult)
    return units


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, *, multi_pod: bool, rules_name: str = None,
            moe_impl: str = None, moe_cap: float = None) -> dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    if moe_impl:
        cfg = _dc.replace(cfg, moe_impl=moe_impl)
    if moe_cap:
        cfg = _dc.replace(cfg, moe_capacity_factor=moe_cap)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules_name = rules_name or ("train" if shape.mode == "train" else "serve")
    rules = shd.RULE_SETS[rules_name]
    api = build_model(cfg)
    b, s = shape.global_batch, shape.seq_len
    t0 = time.time()

    if cfg.arch_type == "encdec":
        units = _encdec_units(cfg, shape.mode, b, 1 if shape.mode == "decode" else s,
                              mesh, rules, enc_len=4096)
    elif shape.mode == "decode":
        units = _decode_units(cfg, b, s, mesh, rules)
    else:
        units = _dec_units(cfg, shape.mode, b, s, mesh, rules)

    head_s = 1 if shape.mode == "decode" else s
    head = _head_unit(cfg, shape.mode, b, head_s, mesh, rules)
    total = _add(head, *[_scaled(u, k) for u, k in units.values()])
    parts = {name: {"unit": u, "mult": k} for name, (u, k) in units.items()}
    parts["head"] = {"unit": head, "mult": 1}
    if shape.mode == "train":
        opt = _opt_unit(cfg, api, mesh, rules)
        total = _add(total, opt)
        parts["opt"] = {"unit": opt, "mult": 1}

    # MODEL_FLOPS (global): 6 N D for train, 2 N D otherwise; D = tokens.
    tokens = b * (1 if shape.mode == "decode" else s)
    n_active = cfg.active_param_count
    model_flops = (6 if shape.mode == "train" else 2) * n_active * tokens
    chips = mesh.size
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "chips": chips, "rules": rules_name,
        "per_device": total,
        "parts": parts,
        "roofline_s": {
            "compute": total["flops"] / HW["peak_flops_bf16"],
            "memory": total["bytes"] / HW["hbm_bw"],
            "collective": total["coll"] / HW["ici_bw"],
        },
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / max(total["flops"] * chips, 1.0),
        "wall_s": round(time.time() - t0, 1),
    }
    terms = res["roofline_s"]
    res["bottleneck"] = max(terms, key=terms.get)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--moe-cap", type=float, default=None)
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = tuple(INPUT_SHAPES) if args.shape == "all" else (args.shape,)
    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}_{'pod2' if args.multi_pod else 'pod1'}"
            if args.rules:
                tag += f"_{args.rules}"
            if args.moe_impl:
                tag += f"_{args.moe_impl}"
            if args.moe_cap:
                tag += f"_cap{args.moe_cap}"
            try:
                res = run_one(arch, shape, multi_pod=args.multi_pod,
                              rules_name=args.rules, moe_impl=args.moe_impl,
                              moe_cap=args.moe_cap)
            except Exception as e:
                res = {"arch": arch, "shape": shape, "error": repr(e)[:2000]}
                print(f"FAIL {tag}: {repr(e)[:300]}")
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1)
            if "roofline_s" in res:
                r = res["roofline_s"]
                print(f"OK {tag}: compute={r['compute']:.4f} memory={r['memory']:.4f} "
                      f"coll={r['collective']:.4f} bn={res['bottleneck']} "
                      f"useful={res['useful_flops_ratio']:.3f}")
            elif "skipped" in res:
                print(f"SKIP {tag}")


if __name__ == "__main__":
    main()
