"""Training launcher.

CPU/laptop: reduced configs train for real (--reduced).  Production: the
same script lowers the full config onto the pod mesh (see dryrun.py for
the no-hardware path).

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt /tmp/ck.npz
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.model import build_model
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_step import build_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    api = build_model(cfg)
    params = api.init(jax.random.key(args.seed))
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                              total_steps=args.steps)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(build_train_step(api, opt_cfg))
    data = SyntheticTokens(DataConfig(cfg.vocab_size, args.seq, args.batch, args.seed))

    t0 = time.time()
    for i in range(args.steps):
        raw = data.batch(i)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.frontend == "vision":
            fe = min(cfg.frontend_tokens, args.seq)
            batch["frontend_embeds"] = jnp.zeros((args.batch, fe, cfg.d_model), jnp.bfloat16)
        if cfg.arch_type == "encdec":
            batch["frontend_embeds"] = jnp.zeros((args.batch, args.seq, cfg.d_model), jnp.bfloat16)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} lr={float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params, "opt": opt_state})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
