"""Production mesh factories.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets --xla_force_host_platform_device_count=512 before
any jax import and then calls these.
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))


def make_host_mesh():
    """Whatever devices exist, as a 1D 'data' mesh (CPU smoke tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), axis_types=_auto(1))


# TPU v5e-class hardware model used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
    "hbm_bytes": 16 * 2**30,
}
