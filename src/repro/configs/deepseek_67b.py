"""DeepSeek 67B — dense GQA, llama-architecture [arXiv:2401.02954]."""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-67b",
    arch_type="dense",
    num_layers=95,
    d_model=8_192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_016,
    vocab_size=102_400,
    rope_theta=10_000.0,
    source="arXiv:2401.02954 (DeepSeek LLM), Table 2",
)
REDUCED = reduced(CONFIG)
