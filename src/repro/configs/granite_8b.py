"""Granite 8B Code — dense GQA, llama-architecture [arXiv:2405.04324]."""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=49_152,
    source="arXiv:2405.04324 (Granite Code Models), Table 1",
)
REDUCED = reduced(CONFIG)
