"""Mixtral 8x7B — MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,              # per-expert FFN width
    vocab_size=32_000,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4_096,     # SWA -> sub-quadratic decode state (long_500k)
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088 (Mixtral of Experts), §2",
)
REDUCED = reduced(CONFIG)
