"""Qwen2-VL 2B — VLM decoder backbone with M-RoPE [arXiv:2409.12191].

The ViT vision encoder + projector are a STUB: ``input_specs`` supplies
precomputed patch embeddings (dynamic-resolution frontend output) which
overwrite the leading positions of the token embedding sequence; M-RoPE
(temporal/height/width rotary sections) runs in the backbone.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    arch_type="dense",
    num_layers=28,
    d_model=1_536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8_960,
    vocab_size=151_936,
    mrope=True,
    frontend="vision",
    frontend_tokens=256,
    rope_theta=1_000_000.0,
    source="arXiv:2409.12191 (Qwen2-VL), §2 + model card",
)
REDUCED = reduced(CONFIG)
