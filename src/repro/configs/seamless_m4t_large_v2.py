"""SeamlessM4T-Large v2 — multimodal encoder-decoder [arXiv:2308.11596].

The speech frontend (mel filterbank + w2v-BERT conformer stack) is a STUB:
the encoder consumes precomputed frame embeddings of shape
(batch, frames, d_model).  This config is the text/unit transformer
backbone: 24 encoder + 24 decoder layers, MHA (kv == heads).
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="encdec",
    num_layers=24,
    encoder_layers=24,
    d_model=1_024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8_192,
    vocab_size=256_206,
    ffn_type="gelu",
    frontend="audio",
    source="arXiv:2308.11596 (SeamlessM4T), §5 + model card",
)
REDUCED = reduced(CONFIG)
