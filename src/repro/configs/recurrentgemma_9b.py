"""RecurrentGemma 9B — Griffin hybrid: RG-LRU + local attention, 1 attention
per 2 recurrent blocks [arXiv:2402.19427]."""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4_096,
    num_heads=16,
    num_kv_heads=1,           # MQA on the local-attention blocks
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "attn"),
    attention_window=2_048,
    lru_width=4_096,
    ssm_conv_width=4,
    source="arXiv:2402.19427 (Griffin) + RecurrentGemma-9B model card",
)
REDUCED = reduced(CONFIG)
