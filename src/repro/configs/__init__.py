"""Assigned-architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "seamless-m4t-large-v2",
    "llama3-405b",
    "qwen2-vl-2b",
    "deepseek-67b",
    "minitron-4b",
    "granite-8b",
    "granite-moe-1b-a400m",
    "mamba2-370m",
    "recurrentgemma-9b",
    "mixtral-8x7b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_") for a in ARCH_IDS}


def get_config(arch_id: str, *, reduced: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(*, reduced: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, reduced=reduced) for a in ARCH_IDS}
