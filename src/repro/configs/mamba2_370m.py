"""Mamba-2 370M — attention-free SSM with SSD [arXiv:2405.21060]."""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1_024,
    vocab_size=50_280,
    ssm_state_dim=128,
    ssm_head_dim=64,          # d_inner 2048 -> 32 SSD heads
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk_size=128,
    source="arXiv:2405.21060 (Mamba-2 / SSD), Table 9",
)
REDUCED = reduced(CONFIG)
