"""Minitron 4B — pruned Nemotron-4, dense GQA [arXiv:2407.14679]."""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    num_layers=32,
    d_model=3_072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9_216,
    vocab_size=256_000,
    source="arXiv:2407.14679 (Minitron), Table 1",
)
REDUCED = reduced(CONFIG)
