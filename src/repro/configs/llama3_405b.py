"""Llama 3.1 405B — dense GQA decoder [arXiv:2407.21783]."""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llama3-405b",
    arch_type="dense",
    num_layers=126,
    d_model=16_384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53_248,
    vocab_size=128_256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783 (Llama 3 herd), Table 3",
)
REDUCED = reduced(CONFIG)
