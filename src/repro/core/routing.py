"""Workflow-DAG routing: inter-agent request flow as a JAX pytree.

The paper's subject is *collaborative* reasoning — lightweight coordinators
fan requests out to heavyweight specialists — but an exogenous arrival
process alone never exercises that: allocation quality under a workflow is
driven by the *inter-agent dataflow*, not by marginal per-agent rates.
``Workflow`` makes the dataflow a first-class, vmappable object:

* ``route`` is an (N, N) row-substochastic forwarding matrix:
  ``route[i, j]`` is the fraction of requests served at agent i that are
  forwarded to agent j's queue on the *next* step.  The row deficit
  ``1 - route[i].sum()`` is the fraction that **exits the workflow** at i
  (a completed end-to-end request).  A zero matrix is today's independent
  behavior: every served request completes where it was served.
* ``source`` ∈ {0,1}^N marks where exogenous arrivals enter — the simulator
  gates the workload generators by it, so only sources see outside traffic.
* ``sink`` ∈ {0,1}^N marks terminal agents (route row identically zero).
  Intermediate agents of a synthetic DAG may still exit a *fraction* of
  their traffic mid-graph; sinks exit all of it.
* ``fan_out`` (N,) multiplies forwarded copies: a coordinator with
  ``fan_out=3`` spawns three specialist sub-requests per served request
  (``arrivals_endogenous = (served * fan_out) @ route``).  The default of 1
  conserves requests end-to-end: exogenous in = completed + in-flight.

``Workflow`` mirrors ``Fleet`` (``core/agents.py``): arrays are pytree
leaves, the topology name is static aux data, and ``pad_workflow`` /
``stack_workflows`` pad the routing matrix consistently with the fleet's
``active`` mask (padded slots receive nothing, forward nothing) so batches
of workflows vmap as one array program (``core/sweep.py::sweep_workflows``).

Generators cover the canonical multi-agent topologies: ``independent``
(today's behavior), ``coordinator_star``, ``pipeline_chain``,
``hierarchical`` (coordinator → specialists → aggregator), and
``synthetic_workflow(n, seed)`` — a reproducible random DAG.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-5


class _CosmeticName(str):
    """A workflow's display name as pytree aux data that compares equal
    regardless of content: two structurally identical workflows with
    different names share one treedef — and therefore one jit trace — since
    the name is never read inside traced code.  (Without this, sweeping
    ``synthetic_workflow(n, seed)`` over seeds would recompile the scan
    once per seed purely because the name embeds the seed.)"""

    def __eq__(self, other):
        return isinstance(other, _CosmeticName)

    def __ne__(self, other):
        return not isinstance(other, _CosmeticName)

    def __hash__(self):
        return hash(_CosmeticName)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Workflow:
    """Inter-agent request-routing topology over N agent slots.

    Arrays are pytree leaves; ``name`` is cosmetic static aux data —
    workflows flow through ``jit``/``vmap``/``device_put`` exactly like
    ``Fleet``, and same-shape workflows share one compiled trace whatever
    they are called.
    """

    name: str
    route: jnp.ndarray    # (N, N) row-substochastic forwarding matrix
    source: jnp.ndarray   # (N,) 1.0 where exogenous arrivals enter
    sink: jnp.ndarray     # (N,) 1.0 where requests terminate (row == 0)
    fan_out: jnp.ndarray  # (N,) forwarded-copy multiplier, 1.0 = conserving

    # -- pytree protocol: arrays are leaves, the name is static aux data. ----

    def tree_flatten(self):
        return (self.route, self.source, self.sink, self.fan_out), \
            _CosmeticName(self.name)

    @classmethod
    def tree_unflatten(cls, name, children):
        return cls(str(name), *children)

    @property
    def num_agents(self) -> int:
        """Static slot count N (matches the fleet's padded width)."""
        return self.route.shape[-1]

    @property
    def exit_fraction(self) -> jnp.ndarray:
        """Per-agent fraction of served requests that exit the workflow."""
        return jnp.maximum(1.0 - self.route.sum(axis=-1), 0.0)

    def validate(self) -> None:
        """Static sanity constraints (checked eagerly, outside jit)."""
        route = np.asarray(self.route)
        src = np.asarray(self.source)
        snk = np.asarray(self.sink)
        fo = np.asarray(self.fan_out)
        n = route.shape[-1]
        if route.shape[-2:] != (n, n):
            raise ValueError(f"route must be square, got {route.shape}")
        for name, flags in (("source", src), ("sink", snk)):
            if flags.shape[-1] != n:
                raise ValueError(f"{name} width {flags.shape[-1]} != {n}")
            if not np.isin(flags, (0.0, 1.0)).all():
                raise ValueError(f"{name} flags must be 0/1: {flags}")
        if (route < -_EPS).any():
            raise ValueError(f"route must be nonnegative: {route}")
        rows = route.sum(axis=-1)
        if (rows > 1.0 + _EPS).any():
            raise ValueError(
                f"route rows must sum to <= 1 (row deficit exits): {rows}"
            )
        if (np.abs(rows * snk) > _EPS).any():
            raise ValueError("sink agents must have an all-zero route row")
        if (fo < 0).any():
            raise ValueError(f"fan_out must be nonnegative: {fo}")
        if src.sum(axis=-1).min() < 1.0:
            raise ValueError("workflow needs at least one source agent")
        # The routing graph must be a DAG: critical-path metrics and the
        # serving engine's request routing both assume acyclicity (cyclic
        # workflows with damping are future work — see ROADMAP).
        for adj in route.reshape(-1, n, n):
            if _has_cycle(adj > _EPS):
                raise ValueError("route must be acyclic (a workflow DAG)")


def check_workflow(workflow: "Workflow", num_agents: int) -> None:
    """The one workflow/fleet compatibility contract, shared by
    ``simulate()``, ``FleetEngine`` and ``sweep_workflows``: the workflow
    must validate and span exactly the fleet's slot count (padding included
    — ``pad_workflow`` a narrower topology explicitly; implicit padding
    would dilute masked metrics with zero-traffic agents)."""
    if np.asarray(workflow.route).ndim != 2:
        raise ValueError(
            f"workflow {workflow.name!r} is batched (route shape "
            f"{np.asarray(workflow.route).shape}); unbatched entry points "
            "take a single topology — batched workflows only flow through "
            "sweep_workflows' vmap"
        )
    workflow.validate()
    if workflow.num_agents != num_agents:
        raise ValueError(
            f"workflow {workflow.name!r} has {workflow.num_agents} agents "
            f"but the fleet has {num_agents}; pad_workflow it explicitly"
        )


def _has_cycle(adj: np.ndarray) -> bool:
    """Kahn's topological sort on a boolean adjacency matrix, O(N + E)."""
    indeg = adj.sum(axis=0)
    ready = [i for i in range(adj.shape[0]) if indeg[i] == 0]
    seen = 0
    while ready:
        i = ready.pop()
        seen += 1
        for j in np.nonzero(adj[i])[0]:
            indeg[j] -= 1
            if indeg[j] == 0:
                ready.append(j)
    return seen < adj.shape[0]


def _workflow(name, route, source, sink, fan_out=None):
    n = route.shape[0]
    return Workflow(
        name=name,
        route=jnp.asarray(route, jnp.float32),
        source=jnp.asarray(source, jnp.float32),
        sink=jnp.asarray(sink, jnp.float32),
        fan_out=jnp.ones(n, jnp.float32) if fan_out is None else
        jnp.asarray(fan_out, jnp.float32),
    )


def independent(n: int) -> Workflow:
    """Today's behavior as a workflow: no routing, every agent is both a
    source and a sink — a served request completes where it was served.
    ``simulate(..., workflow=independent(n))`` is bit-for-bit identical to
    ``simulate(...)`` without a workflow."""
    if n < 1:
        raise ValueError(f"workflow size must be >= 1, got {n}")
    return _workflow("independent", np.zeros((n, n), np.float32),
                     np.ones(n, np.float32), np.ones(n, np.float32))


# Package-level alias (``repro.core.independent_workflow``): the bare name
# ``independent`` is too generic outside this module.
def independent_workflow(n: int) -> Workflow:
    return independent(n)


def coordinator_star(n: int, fan_out: float = 1.0) -> Workflow:
    """Agent 0 is the coordinator (the only source); every served
    coordinator request fans out uniformly to the n-1 specialist sinks."""
    if n < 2:
        raise ValueError(f"coordinator_star needs >= 2 agents, got {n}")
    route = np.zeros((n, n), np.float32)
    route[0, 1:] = 1.0 / (n - 1)
    source = np.zeros(n, np.float32)
    source[0] = 1.0
    sink = np.ones(n, np.float32)
    sink[0] = 0.0
    fo = np.ones(n, np.float32)
    fo[0] = fan_out
    return _workflow("coordinator_star", route, source, sink, fo)


def pipeline_chain(n: int) -> Workflow:
    """Sequential stages: agent 0 (source) → 1 → … → n-1 (sink)."""
    if n < 1:
        raise ValueError(f"workflow size must be >= 1, got {n}")
    route = np.zeros((n, n), np.float32)
    for i in range(n - 1):
        route[i, i + 1] = 1.0
    source = np.zeros(n, np.float32)
    source[0] = 1.0
    sink = np.zeros(n, np.float32)
    sink[n - 1] = 1.0
    return _workflow("pipeline_chain", route, source, sink)


def hierarchical(n: int, fan_out: float = 1.0) -> Workflow:
    """Coordinator (agent 0, source) fans out to n-2 specialists; every
    specialist forwards to the aggregator (agent n-1, the only sink)."""
    if n < 3:
        raise ValueError(f"hierarchical needs >= 3 agents, got {n}")
    route = np.zeros((n, n), np.float32)
    route[0, 1:n - 1] = 1.0 / (n - 2)
    route[1:n - 1, n - 1] = 1.0
    source = np.zeros(n, np.float32)
    source[0] = 1.0
    sink = np.zeros(n, np.float32)
    sink[n - 1] = 1.0
    fo = np.ones(n, np.float32)
    fo[0] = fan_out
    return _workflow("hierarchical", route, source, sink, fo)


def synthetic_workflow(
    n: int,
    seed: int = 0,
    edge_prob: float = 0.4,
    forward_frac: tuple[float, float] = (0.4, 0.9),
) -> Workflow:
    """A reproducible random DAG over the agent order.

    Edges only go forward (strictly upper-triangular route), so the graph is
    acyclic by construction; each non-terminal agent forwards a random
    fraction of its served requests (drawn from ``forward_frac``) across a
    random successor subset and exits the rest mid-graph.  Sources are the
    in-degree-0 agents (agent 0 always qualifies), sinks the out-degree-0
    ones (agent n-1 always qualifies).
    """
    if n < 1:
        raise ValueError(f"workflow size must be >= 1, got {n}")
    rng = np.random.default_rng(seed)
    route = np.zeros((n, n), np.float32)
    for i in range(n - 1):
        succ = rng.random(n - 1 - i) < edge_prob
        if not succ.any():
            succ[rng.integers(0, n - 1 - i)] = rng.random() < 0.7
        if succ.any():
            w = rng.uniform(0.1, 1.0, int(succ.sum()))
            frac = rng.uniform(*forward_frac)
            route[i, i + 1:][succ] = frac * w / w.sum()
    source = (route.sum(axis=0) == 0).astype(np.float32)
    sink = (route.sum(axis=1) == 0).astype(np.float32)
    return _workflow(f"synthetic_s{seed}", route, source, sink)


def pad_workflow(wf: Workflow, n_max: int) -> Workflow:
    """Pad ``wf`` to ``n_max`` slots, consistent with ``pad_fleet``'s
    ``active`` mask: padded slots receive nothing (zero route column),
    forward nothing (zero route row), take no exogenous arrivals
    (``source=0``) and are not sinks; ``fan_out=1`` keeps them inert."""
    n = wf.num_agents
    if n_max < n:
        raise ValueError(f"cannot pad workflow of {n} agents down to {n_max}")
    if n_max == n:
        return wf
    pad = n_max - n

    def vec(a, fill):
        return jnp.concatenate(
            [jnp.asarray(a, jnp.float32), jnp.full((pad,), fill, jnp.float32)]
        )

    route = jnp.zeros((n_max, n_max), jnp.float32).at[:n, :n].set(
        jnp.asarray(wf.route, jnp.float32)
    )
    return Workflow(
        name=wf.name,
        route=route,
        source=vec(wf.source, 0.0),
        sink=vec(wf.sink, 0.0),
        fan_out=vec(wf.fan_out, 1.0),
    )


def stack_workflows(
    workflows: Sequence[Workflow], n_max: int | None = None
) -> Workflow:
    """Pad ``workflows`` to a common width and stack every leaf along a new
    leading workflow axis — (K, N, N) route, (K, N) flags — ready for
    ``vmap`` over workflows (``core/sweep.py::sweep_workflows``)."""
    if not workflows:
        raise ValueError("stack_workflows needs at least one workflow")
    width = max(w.num_agents for w in workflows)
    n_max = width if n_max is None else n_max
    if n_max < width:
        raise ValueError(f"n_max={n_max} < widest workflow ({width} agents)")
    padded = [pad_workflow(w, n_max) for w in workflows]
    stack = lambda field: jnp.stack([getattr(w, field) for w in padded])
    return Workflow(
        name="+".join(w.name for w in workflows),
        route=stack("route"),
        source=stack("source"),
        sink=stack("sink"),
        fan_out=stack("fan_out"),
    )
