"""Agent profiling from dry-run artifacts (paper §V-C "agent profiling
methodologies", made concrete).

The paper hand-specifies Table I (T_i, R_i).  This module DERIVES them for
any assigned architecture from the roofline artifacts the dry-run already
produced:

  T_i  — decode throughput estimate: global_batch tokens per step over the
         dominant per-device roofline term (compute/memory/collective max),
  R_i  — minimum resource share: the agent's per-device parameter+cache
         footprint relative to chip HBM (a model that fills 30% of HBM
         cannot usefully run below ~that share of the pod),
  M_i  — parameter bytes in MB.

`fleet_from_archs` then builds a paper-compatible Fleet, so the allocator,
simulator and serving engine run on *measured* profiles instead of
hand-picked constants — the paper's methodology upgraded with real system
introspection.
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.agents import AgentSpec, Fleet
from repro.launch.mesh import HW


def load_roofline(arch: str, shape: str = "decode_32k",
                  root: str = "experiments/roofline") -> dict | None:
    path = os.path.join(root, f"{arch}_{shape}_pod1.json")
    if not os.path.exists(path):
        return None
    d = json.load(open(path))
    return d if "roofline_s" in d else None


def profile_arch(arch: str, *, root: str = "experiments/roofline",
                 dryrun_root: str = "experiments/dryrun") -> dict | None:
    """Derived (T, R, M) for one architecture from recorded artifacts."""
    roof = load_roofline(arch, root=root)
    if roof is None:
        return None
    terms = roof["roofline_s"]
    step_s = max(terms.values())
    batch = 128  # decode_32k global batch
    tput = batch / max(step_s, 1e-9)

    from repro.configs import get_config

    param_bytes = get_config(arch).param_count * 2  # bf16
    chips = roof["chips"]
    # decode footprint per device: params + cache (argument bytes from the
    # whole-step dry-run when available).
    dr_path = os.path.join(dryrun_root, f"{arch}_decode_32k_pod1.json")
    if os.path.exists(dr_path):
        dr = json.load(open(dr_path))
        arg_bytes = (dr.get("per_device") or {}).get("argument_bytes") or param_bytes / chips
    else:
        arg_bytes = param_bytes / chips
    min_share = min(0.9, max(0.02, arg_bytes / HW["hbm_bytes"]))
    return {
        "arch": arch,
        "throughput_tokens_per_s": tput,
        "min_gpu": round(float(min_share), 4),
        "model_mb": param_bytes / 2**20,
        "bottleneck": roof["bottleneck"],
        "step_s": step_s,
    }


def fleet_from_archs(arch_priority: dict[str, int], **kw) -> Fleet:
    """Build a Fleet whose (M, T, R) come from measured artifacts."""
    specs = []
    for arch, pri in arch_priority.items():
        p = profile_arch(arch, **kw)
        if p is None:
            raise FileNotFoundError(
                f"no roofline artifact for {arch}; run repro.launch.roofline first"
            )
        specs.append(AgentSpec(arch, p["model_mb"], p["throughput_tokens_per_s"],
                               p["min_gpu"], pri))
    return Fleet.from_specs(specs)


def available_archs(root: str = "experiments/roofline") -> list[str]:
    out = []
    for f in glob.glob(os.path.join(root, "*_decode_32k_pod1.json")):
        d = json.load(open(f))
        if "roofline_s" in d:
            out.append(d["arch"])
    return sorted(set(out))
