"""Paper Eq. (2): min over allocations of  alpha·L + beta·C − gamma·H.

Used by tests (the adaptive policy should score no worse than round-robin)
and by the beyond-paper greedy objective-descent experiments.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    alpha: float = 1.0   # latency weight
    beta: float = 1.0    # cost weight
    gamma: float = 1.0   # throughput weight (negated: reward)


def step_objective(
    g: jnp.ndarray,
    queue: jnp.ndarray,
    lam: jnp.ndarray,
    base_throughput: jnp.ndarray,
    weights: ObjectiveWeights = ObjectiveWeights(),
    price_per_second: float = 0.0002,
    latency_cap: float = 1000.0,
    warm_instances: jnp.ndarray | float = 1.0,
) -> jnp.ndarray:
    """One-step value of Eq. (2) for allocation g at state (queue, lam).

    ``warm_instances`` lets a caller price the step's warm-pool size
    (``SimTrace.warm``) into the cost term — warm-instance-seconds billing
    instead of a constant.  Nothing in the allocation path passes it (the
    allocator optimizes latency/throughput only; capacity decisions live in
    ``core/capacity.py``); the default of 1.0 is the paper's provisioned
    single-device setting, where the cost term is constant across
    allocations.
    """
    capacity = g * base_throughput
    served = jnp.minimum(capacity, queue + lam)
    new_queue = queue + lam - served
    latency = jnp.minimum(new_queue / jnp.maximum(capacity, _EPS), latency_cap)
    l_term = latency.mean()
    c_term = price_per_second * warm_instances  # warm-instance-seconds billing
    h_term = served.sum()
    return weights.alpha * l_term + weights.beta * c_term - weights.gamma * h_term
