"""Paper Eq. (2): min over allocations of  alpha·L + beta·C − gamma·H.

Used by tests (the adaptive policy should score no worse than round-robin)
and by the beyond-paper greedy objective-descent experiments.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    alpha: float = 1.0   # latency weight
    beta: float = 1.0    # cost weight
    gamma: float = 1.0   # throughput weight (negated: reward)


def step_objective(
    g: jnp.ndarray,
    queue: jnp.ndarray,
    lam: jnp.ndarray,
    base_throughput: jnp.ndarray,
    weights: ObjectiveWeights = ObjectiveWeights(),
    price_per_second: float = 0.0002,
    latency_cap: float = 1000.0,
) -> jnp.ndarray:
    """One-step value of Eq. (2) for allocation g at state (queue, lam)."""
    capacity = g * base_throughput
    served = jnp.minimum(capacity, queue + lam)
    new_queue = queue + lam - served
    latency = jnp.minimum(new_queue / jnp.maximum(capacity, _EPS), latency_cap)
    l_term = latency.mean()
    c_term = price_per_second  # provisioned device: constant across g
    h_term = served.sum()
    return weights.alpha * l_term + weights.beta * c_term - weights.gamma * h_term
