"""Vmapped (policy × workload) sweep grid — the evaluation surface.

The paper's claim (Table II / Fig. 2) is comparative: adaptive vs baselines
across workloads.  This module evaluates the *entire* policy registry
against a scenario library in ONE jitted call:

    sweep(fleet, scenario_library(rates))  ->  SweepResult

Internally ``jax.vmap`` runs over the policy-id axis and, nested, over the
stacked arrival matrices; per-cell Table II metrics are reduced inside the
jit so the host only materializes a small (P, W, M) grid (plus full traces
when ``keep_traces=True``).  Adding a policy to the allocator registry or a
scenario to the library grows the grid with no other edits.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocator as alloc
from repro.core import workload
from repro.core.agents import Fleet
from repro.core.simulator import (
    METRIC_NAMES,
    SimConfig,
    SimSummary,
    SimTrace,
    simulate_core,
    trace_metrics,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named (S, N) arrival matrix; one workload column of the grid."""

    name: str
    arrivals: jnp.ndarray


def scenario_library(
    rates: Sequence[float] | jnp.ndarray,
    num_steps: int = 100,
    seed: int = 0,
) -> tuple[Scenario, ...]:
    """The standard 8-scenario library over one base rate vector.

    Covers the paper's workloads (constant = Table II, overload / spike /
    dominated = §V-B) plus the beyond-paper diurnal, bursty (per-agent MMPP)
    and correlated (fleet-wide surge) processes.  Stochastic scenarios are
    keyed off ``seed`` and fully reproducible.
    """
    rates = jnp.asarray(rates, jnp.float32)
    n = int(rates.shape[0])
    k_poisson, k_bursty, k_corr = jax.random.split(jax.random.key(seed), 3)
    return (
        Scenario("constant", workload.constant(rates, num_steps)),
        Scenario("poisson", workload.poisson(rates, num_steps, k_poisson)),
        Scenario(
            "spike",
            workload.spike(
                rates, num_steps,
                spike_agent=n - 1,
                spike_start=num_steps // 2,
                spike_len=max(num_steps // 10, 1),
            ),
        ),
        Scenario("overload_3x", workload.scaled(rates, num_steps, 3.0)),
        Scenario("dominated", workload.dominated(rates, num_steps, agent=0, share=0.9)),
        Scenario("diurnal", workload.diurnal(rates, num_steps)),
        Scenario("bursty", workload.bursty(rates, num_steps, k_bursty)),
        Scenario("correlated", workload.correlated(rates, num_steps, k_corr)),
    )


@dataclasses.dataclass(frozen=True)
class SweepSummary:
    """Flat Table-II-style rows, one per (policy, scenario) cell."""

    columns: tuple[str, ...]
    rows: tuple[tuple, ...]

    def to_csv_lines(self) -> list[str]:
        out = [",".join(self.columns)]
        for row in self.rows:
            out.append(",".join(
                f"{v:.4f}" if isinstance(v, float) else str(v) for v in row
            ))
        return out

    def best(self, metric: str = "avg_latency", minimize: bool = True) -> dict[str, str]:
        """Winning policy per scenario under one metric."""
        mi = self.columns.index(metric)
        si = self.columns.index("scenario")
        pi = self.columns.index("policy")
        winners: dict[str, tuple[str, float]] = {}
        for row in self.rows:
            scen, pol, val = row[si], row[pi], row[mi]
            if scen not in winners or (val < winners[scen][1]) == minimize:
                winners[scen] = (pol, val)
        return {scen: pol for scen, (pol, _) in winners.items()}


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Raw grids from one sweep; axes are (policy, scenario[, agent])."""

    policy_names: tuple[str, ...]
    scenario_names: tuple[str, ...]
    metrics: np.ndarray               # (P, W, len(METRIC_NAMES)) float32
    per_agent_latency: np.ndarray     # (P, W, N)
    per_agent_throughput: np.ndarray  # (P, W, N)
    cost: float                       # provisioned $, identical across cells
    config: SimConfig
    traces: SimTrace | None = None    # leaves (P, W, S, N) when kept

    def metric(self, name: str) -> np.ndarray:
        return self.metrics[..., METRIC_NAMES.index(name)]

    def summary(self, policy: str, scenario: str) -> SimSummary:
        """One cell as a ``SimSummary`` — same fields as ``run_policy``."""
        p = self.policy_names.index(policy)
        w = self.scenario_names.index(scenario)
        m = dict(zip(METRIC_NAMES, (float(x) for x in self.metrics[p, w])))
        return SimSummary(
            policy=policy,
            avg_latency=m["avg_latency"],
            latency_std=m["latency_std"],
            per_agent_latency=tuple(float(x) for x in self.per_agent_latency[p, w]),
            total_throughput=m["total_throughput"],
            per_agent_throughput=tuple(float(x) for x in self.per_agent_throughput[p, w]),
            cost=self.cost,
            gpu_utilization=m["gpu_utilization"],
            littles_law_latency=m["littles_law_latency"],
            mean_queue=m["mean_queue"],
        )

    def table(self) -> SweepSummary:
        columns = ("policy", "scenario") + METRIC_NAMES + ("cost",)
        rows = []
        for p, pol in enumerate(self.policy_names):
            for w, scen in enumerate(self.scenario_names):
                rows.append(
                    (pol, scen) + tuple(float(x) for x in self.metrics[p, w])
                    + (self.cost,)
                )
        return SweepSummary(columns=columns, rows=tuple(rows))


@functools.partial(
    jax.jit,
    static_argnames=("fleet_static", "config", "reg_names", "keep_traces"),
)
def _sweep_jit(
    pids: jnp.ndarray,
    arrivals: jnp.ndarray,
    fleet_arrays: tuple,
    fleet_static: tuple,
    config: SimConfig,
    reg_names: tuple,
    keep_traces: bool,
):
    fleet = Fleet(fleet_static, *fleet_arrays)

    def cell(pid, arr):
        trace = simulate_core(pid, arr, fleet, config, reg_names)
        vec, per_lat, per_tput = trace_metrics(trace)
        if keep_traces:
            return vec, per_lat, per_tput, trace
        return vec, per_lat, per_tput

    return jax.vmap(lambda pid: jax.vmap(lambda a: cell(pid, a))(arrivals))(pids)


def sweep(
    fleet: Fleet,
    scenarios: Sequence[Scenario],
    config: SimConfig = SimConfig(),
    policies: Sequence[str] | None = None,
    keep_traces: bool = False,
) -> SweepResult:
    """Evaluate ``policies`` (default: the whole registry) × ``scenarios``.

    All scenarios must share one (S, N) shape.  The grid is a single jitted
    ``vmap(policy) ∘ vmap(workload)`` call over ``simulate_core`` (cached
    across calls with the same fleet/config/registry); the cost column is
    computed host-side (it is allocation-independent).
    """
    fleet.validate()
    reg_names = alloc.policy_names()
    names = reg_names if policies is None else tuple(policies)
    pids = jnp.asarray([alloc.policy_id(p) for p in names])
    arrivals = jnp.stack(
        [jnp.asarray(s.arrivals, jnp.float32) for s in scenarios]
    )  # (W, S, N)

    fleet_arrays = (fleet.model_size_mb, fleet.base_throughput, fleet.min_gpu, fleet.priority)
    out = _sweep_jit(
        pids, arrivals, fleet_arrays, fleet.names, config, reg_names, keep_traces
    )
    metrics, per_lat, per_tput = (np.asarray(x) for x in out[:3])
    traces = out[3] if keep_traces else None

    num_steps = arrivals.shape[1]
    cost = config.num_gpus * num_steps / 3600.0 * config.price_per_hour
    return SweepResult(
        policy_names=names,
        scenario_names=tuple(s.name for s in scenarios),
        metrics=metrics,
        per_agent_latency=per_lat,
        per_agent_throughput=per_tput,
        cost=float(cost),
        config=config,
        traces=traces,
    )
