"""Vmapped (fleet × policy × workload) sweep grids — the evaluation surface.

The paper's claim (Table II / Fig. 2) is comparative: adaptive vs baselines
across workloads.  This module evaluates the *entire* policy registry
against a scenario library in ONE jitted call, and — because ``Fleet`` is a
registered pytree with an agent-validity mask (``core/agents.py``) — scales
that grid along a third, batched **fleet axis** of heterogeneous fleet
sizes:

    sweep(fleet, scenario_library(rates))          ->  SweepResult (P, W)
    sweep_fleets([fleet_4, ..., fleet_256])        ->  SweepResult (F, P, W)

``sweep`` nests ``vmap(policy) ∘ vmap(workload)`` over ``simulate_core``;
``sweep_fleets`` pads every fleet to a common width, stacks them
(``stack_fleets``), builds one matched, padded scenario column per fleet
(``fleet_scenario_library``), and adds ``vmap(fleet)`` outermost.  Padded
slots contribute zero demand, receive exactly g = 0 from every registered
policy, and are excluded from all metric reductions, so each row of the
batched grid matches the per-fleet unbatched ``sweep`` within float
tolerance.

The batched grid is **device-sharded**: the fleet axis is laid out across
``jax.devices()`` with a 1D mesh + ``NamedSharding`` (the
``launch/mesh.py`` / ``distributed/sharding.py`` conventions: non-divisible
axes fall back to replication), producing identical metrics on a single
device and near-linear scaling on many.

Per-cell Table II metrics are reduced inside the jit so the host only
materializes a small (…, P, W, M) grid (plus full traces when
``keep_traces=True``).  Adding a policy to the allocator registry or a
scenario to the library grows the grid with no other edits.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import allocator as alloc
from repro.core import workload
from repro.core.agents import Fleet, stack_fleets
from repro.core.simulator import (
    METRIC_NAMES,
    SimConfig,
    SimSummary,
    SimTrace,
    simulate_core,
    trace_metrics,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named (S, N) arrival matrix; one workload column of the grid."""

    name: str
    arrivals: jnp.ndarray


def scenario_library(
    rates: Sequence[float] | jnp.ndarray,
    num_steps: int = 100,
    seed: int = 0,
) -> tuple[Scenario, ...]:
    """The standard 8-scenario library over one base rate vector.

    Covers the paper's workloads (constant = Table II, overload / spike /
    dominated = §V-B) plus the beyond-paper diurnal, bursty (per-agent MMPP)
    and correlated (fleet-wide surge) processes.  Stochastic scenarios are
    keyed off ``seed`` and fully reproducible.
    """
    rates = jnp.asarray(rates, jnp.float32)
    n = int(rates.shape[0])
    k_poisson, k_bursty, k_corr = jax.random.split(jax.random.key(seed), 3)
    return (
        Scenario("constant", workload.constant(rates, num_steps)),
        Scenario("poisson", workload.poisson(rates, num_steps, k_poisson)),
        Scenario(
            "spike",
            workload.spike(
                rates, num_steps,
                spike_agent=n - 1,
                spike_start=num_steps // 2,
                spike_len=max(num_steps // 10, 1),
            ),
        ),
        Scenario("overload_3x", workload.scaled(rates, num_steps, 3.0)),
        Scenario("dominated", workload.dominated(rates, num_steps, agent=0, share=0.9)),
        Scenario("diurnal", workload.diurnal(rates, num_steps)),
        Scenario("bursty", workload.bursty(rates, num_steps, k_bursty)),
        Scenario("correlated", workload.correlated(rates, num_steps, k_corr)),
    )


def fleet_scenario_library(
    rate_vectors: Sequence[Sequence[float] | jnp.ndarray],
    n_max: int,
    num_steps: int = 100,
    seed: int = 0,
) -> tuple[tuple[str, ...], jnp.ndarray]:
    """Matched per-fleet scenario columns, padded to a common agent width.

    Each rate vector gets the standard library generated *at its own size*
    (so stochastic draws match what the unbatched ``scenario_library`` would
    produce for that fleet) and is then zero-padded to ``n_max`` agents.
    Returns ``(scenario_names, arrivals)`` with arrivals of shape
    (F, W, S, n_max) — the workload block of one batched fleet sweep.
    """
    names: tuple[str, ...] | None = None
    blocks = []
    for rates in rate_vectors:
        lib = scenario_library(rates, num_steps, seed)
        lib_names = tuple(s.name for s in lib)
        if names is None:
            names = lib_names
        elif names != lib_names:
            raise ValueError("scenario libraries diverged across fleets")
        stacked = np.stack([np.asarray(s.arrivals, np.float32) for s in lib])
        pad = n_max - stacked.shape[-1]
        if pad < 0:
            raise ValueError(
                f"rate vector wider ({stacked.shape[-1]}) than n_max={n_max}"
            )
        blocks.append(np.pad(stacked, ((0, 0), (0, 0), (0, pad))))
    return names, jnp.asarray(np.stack(blocks))


@dataclasses.dataclass(frozen=True)
class SweepSummary:
    """Flat Table-II-style rows, one per (fleet,) policy, scenario cell."""

    columns: tuple[str, ...]
    rows: tuple[tuple, ...]

    def to_csv_lines(self) -> list[str]:
        out = [",".join(self.columns)]
        for row in self.rows:
            out.append(",".join(
                f"{v:.4f}" if isinstance(v, float) else str(v) for v in row
            ))
        return out

    def best(self, metric: str = "avg_latency", minimize: bool = True) -> dict[str, str]:
        """Winning policy per scenario (per fleet/scenario when the table
        has a fleet axis) under one metric.

        Comparisons are strict, so exact ties are stable: the first row in
        table order (= policy-registry order) keeps the win in both the
        minimize and maximize directions.
        """
        mi = self.columns.index(metric)
        si = self.columns.index("scenario")
        pi = self.columns.index("policy")
        fi = self.columns.index("fleet") if "fleet" in self.columns else None
        winners: dict[str, tuple[str, float]] = {}
        for row in self.rows:
            key = row[si] if fi is None else f"{row[fi]}/{row[si]}"
            val = row[mi]
            if key not in winners:
                winners[key] = (row[pi], val)
                continue
            better = val < winners[key][1] if minimize else val > winners[key][1]
            if better:
                winners[key] = (row[pi], val)
        return {key: pol for key, (pol, _) in winners.items()}


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Raw grids from one sweep; axes are ([fleet,] policy, scenario[, agent]).

    ``fleet_names`` is None for a plain 2-axis ``sweep``; when set (the
    ``sweep_fleets`` path) every grid carries a leading fleet axis.
    """

    policy_names: tuple[str, ...]
    scenario_names: tuple[str, ...]
    metrics: np.ndarray               # ([F,] P, W, len(METRIC_NAMES)) float32
    per_agent_latency: np.ndarray     # ([F,] P, W, N)
    per_agent_throughput: np.ndarray  # ([F,] P, W, N)
    cost: float                       # provisioned $, identical across cells
    config: SimConfig
    traces: SimTrace | None = None    # leaves ([F,] P, W, S, N) when kept
    fleet_names: tuple[str, ...] | None = None

    def metric(self, name: str) -> np.ndarray:
        return self.metrics[..., METRIC_NAMES.index(name)]

    def _cell_index(self, policy: str, scenario: str, fleet: str | None):
        p = self.policy_names.index(policy)
        w = self.scenario_names.index(scenario)
        if self.fleet_names is None:
            if fleet is not None:
                raise ValueError("this sweep has no fleet axis")
            return (p, w)
        if fleet is None:
            raise ValueError(f"fleet axis present; pick one of {self.fleet_names}")
        return (self.fleet_names.index(fleet), p, w)

    def summary(
        self, policy: str, scenario: str, fleet: str | None = None
    ) -> SimSummary:
        """One cell as a ``SimSummary`` — same fields as ``run_policy``."""
        idx = self._cell_index(policy, scenario, fleet)
        m = dict(zip(METRIC_NAMES, (float(x) for x in self.metrics[idx])))
        return SimSummary(
            policy=policy,
            avg_latency=m["avg_latency"],
            latency_std=m["latency_std"],
            per_agent_latency=tuple(float(x) for x in self.per_agent_latency[idx]),
            total_throughput=m["total_throughput"],
            per_agent_throughput=tuple(float(x) for x in self.per_agent_throughput[idx]),
            cost=self.cost,
            gpu_utilization=m["gpu_utilization"],
            littles_law_latency=m["littles_law_latency"],
            mean_queue=m["mean_queue"],
        )

    def table(self) -> SweepSummary:
        base = ("policy", "scenario") + METRIC_NAMES + ("cost",)
        # One loop serves both shapes: a fleetless grid is a single
        # anonymous fleet whose prefix column is dropped.
        has_fleet = self.fleet_names is not None
        fleet_axis = self.fleet_names if has_fleet else (None,)
        rows = []
        for f, fl in enumerate(fleet_axis):
            grid = self.metrics[f] if has_fleet else self.metrics
            for p, pol in enumerate(self.policy_names):
                for w, scen in enumerate(self.scenario_names):
                    prefix = (fl, pol, scen) if has_fleet else (pol, scen)
                    rows.append(
                        prefix + tuple(float(x) for x in grid[p, w]) + (self.cost,)
                    )
        columns = (("fleet",) + base) if has_fleet else base
        return SweepSummary(columns=columns, rows=tuple(rows))


@functools.partial(jax.jit, static_argnames=("config", "reg_names", "keep_traces"))
def _sweep_jit(
    pids: jnp.ndarray,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig,
    reg_names: tuple,
    keep_traces: bool,
):
    def cell(pid, arr):
        trace = simulate_core(pid, arr, fleet, config, reg_names)
        vec, per_lat, per_tput = trace_metrics(trace, fleet.active)
        if keep_traces:
            return vec, per_lat, per_tput, trace
        return vec, per_lat, per_tput

    return jax.vmap(lambda pid: jax.vmap(lambda a: cell(pid, a))(arrivals))(pids)


@functools.partial(jax.jit, static_argnames=("config", "reg_names", "keep_traces"))
def _fleet_sweep_jit(
    pids: jnp.ndarray,
    arrivals: jnp.ndarray,  # (F, W, S, N)
    fleet: Fleet,           # leaves (F, N)
    config: SimConfig,
    reg_names: tuple,
    keep_traces: bool,
):
    def cell(fl, pid, arr):
        trace = simulate_core(pid, arr, fl, config, reg_names)
        vec, per_lat, per_tput = trace_metrics(trace, fl.active)
        if keep_traces:
            return vec, per_lat, per_tput, trace
        return vec, per_lat, per_tput

    over_scen = jax.vmap(cell, in_axes=(None, None, 0))
    over_pol = jax.vmap(over_scen, in_axes=(None, 0, None))
    over_fleet = jax.vmap(over_pol, in_axes=(0, None, 0))
    return over_fleet(fleet, pids, arrivals)


def grid_mesh() -> jax.sharding.Mesh:
    """All live devices as a 1D ``grid`` mesh (cf. ``launch.mesh.make_host_mesh``)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("grid",))


def _shard_fleet_axis(stacked: Fleet, arrivals: jnp.ndarray, mesh=None):
    """Lay the fleet axis out across the mesh's ``grid`` axis.

    Follows ``distributed/sharding.py``'s divisibility convention: when the
    fleet count does not divide the device count the axis is replicated
    instead, so the sharded path always runs (and on one device is the
    identity placement — metrics are bit-identical to the unsharded path).
    """
    mesh = grid_mesh() if mesh is None else mesh
    f = arrivals.shape[0]
    if f % mesh.shape["grid"] == 0:
        spec = PartitionSpec("grid")
    else:
        spec = PartitionSpec()
    sharding = NamedSharding(mesh, spec)
    return jax.device_put(stacked, sharding), jax.device_put(arrivals, sharding)


def sweep(
    fleet: Fleet,
    scenarios: Sequence[Scenario],
    config: SimConfig = SimConfig(),
    policies: Sequence[str] | None = None,
    keep_traces: bool = False,
) -> SweepResult:
    """Evaluate ``policies`` (default: the whole registry) × ``scenarios``.

    All scenarios must share one (S, N) shape.  The grid is a single jitted
    ``vmap(policy) ∘ vmap(workload)`` call over ``simulate_core`` (cached
    across calls with the same fleet structure/config/registry); the cost
    column is computed host-side (it is allocation-independent).
    """
    fleet.validate()
    reg_names = alloc.policy_names()
    names = reg_names if policies is None else tuple(policies)
    pids = jnp.asarray([alloc.policy_id(p) for p in names])
    arrivals = jnp.stack(
        [jnp.asarray(s.arrivals, jnp.float32) for s in scenarios]
    )  # (W, S, N)

    out = _sweep_jit(pids, arrivals, fleet, config, reg_names, keep_traces)
    metrics, per_lat, per_tput = (np.asarray(x) for x in out[:3])
    traces = out[3] if keep_traces else None

    num_steps = arrivals.shape[1]
    cost = config.num_gpus * num_steps / 3600.0 * config.price_per_hour
    return SweepResult(
        policy_names=names,
        scenario_names=tuple(s.name for s in scenarios),
        metrics=metrics,
        per_agent_latency=per_lat,
        per_agent_throughput=per_tput,
        cost=float(cost),
        config=config,
        traces=traces,
    )


def sweep_fleets(
    fleets: Sequence[Fleet],
    rate_vectors: Sequence[Sequence[float] | jnp.ndarray] | None = None,
    num_steps: int = 100,
    seed: int = 0,
    config: SimConfig = SimConfig(),
    policies: Sequence[str] | None = None,
    fleet_names: Sequence[str] | None = None,
    keep_traces: bool = False,
    shard: bool = True,
) -> SweepResult:
    """One jitted (fleet × policy × scenario) grid over heterogeneous fleets.

    Fleets are padded to the widest member and stacked into a single batched
    ``Fleet`` pytree; each fleet gets a matched scenario column generated at
    its true size from its own rate vector (default:
    ``workload.synthetic_rates`` at the paper's aggregate load, so total
    demand is held constant while the agent count scales).  ``shard=True``
    lays the fleet axis across ``jax.devices()`` (identical metrics on one
    device); the per-fleet rows match the unbatched ``sweep`` within float
    tolerance.
    """
    fleets = list(fleets)
    if not fleets:
        raise ValueError("sweep_fleets needs at least one fleet")
    for f in fleets:
        f.validate()
    if rate_vectors is None:
        rate_vectors = [
            workload.synthetic_rates(f.num_agents, seed=seed + i)
            for i, f in enumerate(fleets)
        ]
    if len(rate_vectors) != len(fleets):
        raise ValueError("need one rate vector per fleet")
    for i, (f, r) in enumerate(zip(fleets, rate_vectors)):
        width = np.asarray(r).shape[-1]
        if width != f.num_agents:
            raise ValueError(
                f"rate vector {i} has {width} agents but fleet {i} has "
                f"{f.num_agents}; a mismatch would silently zero real demand"
            )
    if fleet_names is None:
        fleet_names = tuple(f"fleet{i}_n{f.num_agents}" for i, f in enumerate(fleets))
    else:
        fleet_names = tuple(fleet_names)

    stacked = stack_fleets(fleets)
    scen_names, arrivals = fleet_scenario_library(
        rate_vectors, stacked.num_agents, num_steps, seed
    )  # (F, W, S, N_max)
    if shard:
        stacked, arrivals = _shard_fleet_axis(stacked, arrivals)

    reg_names = alloc.policy_names()
    names = reg_names if policies is None else tuple(policies)
    pids = jnp.asarray([alloc.policy_id(p) for p in names])

    out = _fleet_sweep_jit(pids, arrivals, stacked, config, reg_names, keep_traces)
    metrics, per_lat, per_tput = (np.asarray(x) for x in out[:3])
    traces = out[3] if keep_traces else None

    cost = config.num_gpus * num_steps / 3600.0 * config.price_per_hour
    return SweepResult(
        policy_names=names,
        scenario_names=scen_names,
        metrics=metrics,
        per_agent_latency=per_lat,
        per_agent_throughput=per_tput,
        cost=float(cost),
        config=config,
        traces=traces,
        fleet_names=fleet_names,
    )
