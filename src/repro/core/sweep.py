"""Vmapped (fleet | workflow | capacity × policy × workload) sweep grids —
the evaluation surface.

The paper's claim (Table II / Fig. 2) is comparative: adaptive vs baselines
across workloads.  This module evaluates the *entire* policy registry
against a scenario library in ONE jitted call, and — because ``Fleet``,
``Workflow`` and ``CapacityConfig`` are registered pytrees
(``core/agents.py`` / ``core/routing.py`` / ``core/capacity.py``) — scales
that grid along a batched **fleet axis** of heterogeneous fleet sizes, a
batched **workflow axis** of routing topologies, or a batched **capacity
axis** of warm-pool autoscalers:

    sweep(fleet, scenario_library(rates))          ->  SweepResult (P, W)
    sweep_fleets([fleet_4, ..., fleet_256])        ->  SweepResult (F, P, W)
    sweep_workflows(fleet, scenarios=...)          ->  SweepResult (K, P, W)
    sweep_capacity(fleet, scenarios=...)           ->  SweepResult (C, P, W)

``sweep`` nests ``vmap(policy) ∘ vmap(workload)`` over ``simulate_core``;
``sweep_fleets`` pads every fleet to a common width, stacks them
(``stack_fleets``), builds one matched, padded scenario column per fleet
(``fleet_scenario_library``), and adds ``vmap(fleet)`` outermost.
``sweep_workflows`` stacks routing topologies (``stack_workflows``) and
adds ``vmap(workflow)`` outermost — policies are ranked under *inter-agent
dataflow*, not just arrival processes; ``workflow_scenario_library`` builds
the canonical topology set for a fleet width.  ``sweep_capacity`` stacks
autoscaler configs (``stack_capacities``) and adds ``vmap(capacity)``
outermost, so every allocation policy is ranked under every elasticity
regime — the cost column of the grid is per-cell (warm-instance-seconds
billing) and genuinely differs across cells; ``capacity_scenario_library``
builds the canonical capacity set (always-on, reactive with and without
cold starts, scale-to-zero).  Padded slots contribute zero demand, receive
exactly g = 0 from every registered policy, are excluded from all metric
reductions, and receive/forward no routed traffic (``pad_workflow``), so
each row of a batched grid matches its unbatched original within float
tolerance.

Every streaming grid is **device-sharded over a 2D mesh** when more than
one device is live (``core/sharding.py``): the batched sweep axis (fleet |
workflow | capacity) lays out over the mesh's ``data`` axis and the
scenario axis — the largest axis in every paper-style grid — over its
``grid`` axis, via ``shard_map`` with the per-cell streaming scan unchanged
inside the shard body and the arrivals block donated
(``donate_argnums``) so large grids stop double-buffering their biggest
input.  Non-divisible axes are padded with copies of row 0 and stripped on
the host side (never the old silent whole-axis replication), so sharded
metrics are identical to unsharded ones; on a single device every entry
point routes through the plain jit and stays bit-identical to the
unsharded kernel.  ``REPRO_SWEEP_SHARD=0`` forces that single-device path
everywhere (the documented debugging escape hatch), and the trace-based
oracle kernel keeps a ``NamedSharding`` layout hint on the fleet axis.

Per-cell Table II metrics are reduced inside the jit so the host only
materializes a small (…, P, W, M) grid (plus full traces when
``keep_traces=True``).  Adding a policy to the allocator registry or a
scenario to the library grows the grid with no other edits.

**Streaming grid kernel** (the default whenever ``keep_traces=False``):
the policy axis is evaluated *inside* the scan by
``simulator.simulate_stream_core`` — each registered policy dispatched
exactly once per step on its own state row (``alloc.policy_stack``),
instead of the vmapped ``lax.switch`` whose lowering evaluates all P
branches per policy row (P² allocator work per grid) — and the
METRIC_NAMES reductions accumulate in the scan carry, so peak memory per
cell is O(P · N) regardless of the horizon instead of materializing all
eight (S, N) trace leaves.  Pass ``stream=False`` (or ``keep_traces=True``)
to run the trace-based kernel, which is kept as the parity oracle:
streaming metrics match it within float tolerance on all four grid types
(tests/test_streaming.py).  ``return_arrays=True`` on any entry point
skips the host transfer and returns raw device arrays — the benchmark
timing surface.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import allocator as alloc
from repro.core import failures as fail_mod
from repro.core import sharding
from repro.core.sharding import grid_mesh  # re-export: the cached 2D mesh
from repro.core import routing
from repro.core import workload
from repro.core.agents import Fleet, stack_fleets
from repro.core.capacity import (
    CapacityConfig,
    capacity_config,
    check_capacity,
    stack_capacities,
)
from repro.core.routing import Workflow, stack_workflows
from repro.core.simulator import (
    METRIC_NAMES,
    SimConfig,
    SimSummary,
    SimTrace,
    resolve_block_size,
    simulate_core,
    simulate_stream_core,
    trace_metrics,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named (S, N) arrival matrix; one workload column of the grid."""

    name: str
    arrivals: jnp.ndarray


def scenario_library(
    rates: Sequence[float] | jnp.ndarray,
    num_steps: int = 100,
    seed: int = 0,
) -> tuple[Scenario, ...]:
    """The standard 8-scenario library over one base rate vector.

    Covers the paper's workloads (constant = Table II, overload / spike /
    dominated = §V-B) plus the beyond-paper diurnal, bursty (per-agent MMPP)
    and correlated (fleet-wide surge) processes.  Stochastic scenarios are
    keyed off ``seed`` and fully reproducible.
    """
    rates = jnp.asarray(rates, jnp.float32)
    n = int(rates.shape[0])
    k_poisson, k_bursty, k_corr = jax.random.split(jax.random.key(seed), 3)
    return (
        Scenario("constant", workload.constant(rates, num_steps)),
        Scenario("poisson", workload.poisson(rates, num_steps, k_poisson)),
        Scenario(
            "spike",
            workload.spike(
                rates, num_steps,
                spike_agent=n - 1,
                spike_start=num_steps // 2,
                spike_len=max(num_steps // 10, 1),
            ),
        ),
        Scenario("overload_3x", workload.scaled(rates, num_steps, 3.0)),
        Scenario("dominated", workload.dominated(rates, num_steps, agent=0, share=0.9)),
        Scenario("diurnal", workload.diurnal(rates, num_steps)),
        Scenario("bursty", workload.bursty(rates, num_steps, k_bursty)),
        Scenario("correlated", workload.correlated(rates, num_steps, k_corr)),
    )


def fleet_scenario_library(
    rate_vectors: Sequence[Sequence[float] | jnp.ndarray],
    n_max: int,
    num_steps: int = 100,
    seed: int = 0,
) -> tuple[tuple[str, ...], jnp.ndarray]:
    """Matched per-fleet scenario columns, padded to a common agent width.

    Each rate vector gets the standard library generated *at its own size*
    (so stochastic draws match what the unbatched ``scenario_library`` would
    produce for that fleet) and is then zero-padded to ``n_max`` agents.
    Returns ``(scenario_names, arrivals)`` with arrivals of shape
    (F, W, S, n_max) — the workload block of one batched fleet sweep.
    """
    names: tuple[str, ...] | None = None
    blocks = []
    for rates in rate_vectors:
        lib = scenario_library(rates, num_steps, seed)
        lib_names = tuple(s.name for s in lib)
        if names is None:
            names = lib_names
        elif names != lib_names:
            raise ValueError("scenario libraries diverged across fleets")
        stacked = np.stack([np.asarray(s.arrivals, np.float32) for s in lib])
        pad = n_max - stacked.shape[-1]
        if pad < 0:
            raise ValueError(
                f"rate vector wider ({stacked.shape[-1]}) than n_max={n_max}"
            )
        blocks.append(np.pad(stacked, ((0, 0), (0, 0), (0, pad))))
    return names, jnp.asarray(np.stack(blocks))


@dataclasses.dataclass(frozen=True)
class SweepSummary:
    """Flat Table-II-style rows, one per (fleet,) policy, scenario cell."""

    columns: tuple[str, ...]
    rows: tuple[tuple, ...]

    def to_csv_lines(self) -> list[str]:
        out = [",".join(self.columns)]
        for row in self.rows:
            out.append(",".join(
                f"{v:.4f}" if isinstance(v, float) else str(v) for v in row
            ))
        return out

    def best(self, metric: str = "avg_latency", minimize: bool = True) -> dict[str, str]:
        """Winning policy per scenario (per fleet/workflow/capacity and
        scenario when the table has a leading batch axis) under one metric.

        Comparisons are strict, so exact ties are stable: the first row in
        table order (= policy-registry order) keeps the win in both the
        minimize and maximize directions.
        """
        mi = self.columns.index(metric)
        si = self.columns.index("scenario")
        pi = self.columns.index("policy")
        fi = next(
            (self.columns.index(c)
             for c in ("fleet", "workflow", "capacity", "failure")
             if c in self.columns),
            None,
        )
        winners: dict[str, tuple[str, float]] = {}
        for row in self.rows:
            key = row[si] if fi is None else f"{row[fi]}/{row[si]}"
            val = row[mi]
            if key not in winners:
                winners[key] = (row[pi], val)
                continue
            better = val < winners[key][1] if minimize else val > winners[key][1]
            if better:
                winners[key] = (row[pi], val)
        return {key: pol for key, (pol, _) in winners.items()}


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Raw grids from one sweep; axes are ([fleet | workflow | capacity,]
    policy, scenario[, agent]).

    ``fleet_names`` / ``workflow_names`` / ``capacity_names`` are None for a
    plain 2-axis ``sweep``; when one is set (the ``sweep_fleets`` /
    ``sweep_workflows`` / ``sweep_capacity`` paths) every grid carries that
    leading batch axis.  Cost is a per-cell metric (``metrics[...,
    METRIC_NAMES.index("cost")]``, warm-instance-seconds billing) — it is
    only constant across cells under an always-on capacity pool.
    """

    policy_names: tuple[str, ...]
    scenario_names: tuple[str, ...]
    metrics: np.ndarray               # ([F|K|C,] P, W, len(METRIC_NAMES)) float32
    per_agent_latency: np.ndarray     # ([F|K|C,] P, W, N)
    per_agent_throughput: np.ndarray  # ([F|K|C,] P, W, N)
    config: SimConfig
    traces: SimTrace | None = None    # leaves ([F|K|C,] P, W, S, N) when kept
    fleet_names: tuple[str, ...] | None = None
    workflow_names: tuple[str, ...] | None = None
    capacity_names: tuple[str, ...] | None = None
    per_agent_queue: np.ndarray | None = None  # ([F|K|C,] P, W, N) per-stage backlog
    failure_names: tuple[str, ...] | None = None

    def _leading_axis(self) -> tuple[str, tuple[str, ...]] | None:
        if self.fleet_names is not None:
            return "fleet", self.fleet_names
        if self.workflow_names is not None:
            return "workflow", self.workflow_names
        if self.capacity_names is not None:
            return "capacity", self.capacity_names
        if self.failure_names is not None:
            return "failure", self.failure_names
        return None

    def metric(self, name: str) -> np.ndarray:
        return self.metrics[..., METRIC_NAMES.index(name)]

    def _cell_index(
        self,
        policy: str,
        scenario: str,
        fleet: str | None,
        workflow: str | None = None,
        capacity: str | None = None,
        failure: str | None = None,
    ):
        p = self.policy_names.index(policy)
        w = self.scenario_names.index(scenario)
        lead = self._leading_axis()
        picked = {"fleet": fleet, "workflow": workflow, "capacity": capacity,
                  "failure": failure}
        if lead is None:
            bad = [k for k, v in picked.items() if v is not None]
            if bad:
                raise ValueError(f"this sweep has no {bad[0]} axis")
            return (p, w)
        axis, names = lead
        if picked[axis] is None:
            raise ValueError(f"{axis} axis present; pick one of {names}")
        for other in picked:
            if other != axis and picked[other] is not None:
                raise ValueError(f"this sweep has no {other} axis")
        return (names.index(picked[axis]), p, w)

    def summary(
        self,
        policy: str,
        scenario: str,
        fleet: str | None = None,
        workflow: str | None = None,
        capacity: str | None = None,
        failure: str | None = None,
    ) -> SimSummary:
        """One cell as a ``SimSummary`` — same fields as ``run_policy``."""
        idx = self._cell_index(
            policy, scenario, fleet, workflow, capacity, failure
        )
        m = dict(zip(METRIC_NAMES, (float(x) for x in self.metrics[idx])))
        per_queue = (
            () if self.per_agent_queue is None else self.per_agent_queue[idx]
        )
        return SimSummary.from_metrics(
            policy, m, self.per_agent_latency[idx],
            self.per_agent_throughput[idx], per_queue,
        )

    def table(self) -> SweepSummary:
        base = ("policy", "scenario") + METRIC_NAMES
        # One loop serves all shapes: an unbatched grid is a single
        # anonymous leading slot whose prefix column is dropped.
        lead = self._leading_axis()
        lead_names = (None,) if lead is None else lead[1]
        rows = []
        for f, fl in enumerate(lead_names):
            grid = self.metrics if lead is None else self.metrics[f]
            for p, pol in enumerate(self.policy_names):
                for w, scen in enumerate(self.scenario_names):
                    prefix = (pol, scen) if lead is None else (fl, pol, scen)
                    rows.append(prefix + tuple(float(x) for x in grid[p, w]))
        columns = base if lead is None else ((lead[0],) + base)
        return SweepSummary(columns=columns, rows=tuple(rows))


@functools.partial(
    jax.jit, static_argnames=("config", "reg_names", "keep_traces", "batch_axis")
)
def _grid_jit(
    pids: jnp.ndarray,
    arrivals: jnp.ndarray,   # (W, S, N), or (F, W, S, N) when batch_axis="fleet"
    fleet: Fleet,            # leaves (N,), or (F, N) when batch_axis="fleet"
    workflow: Workflow | None,  # leaves (K, N, N)/(K, N) when batch_axis="workflow"
    capacity: CapacityConfig | None,  # leaves (C,) when batch_axis="capacity"
    fspec,                   # FailureSpec | None; leaves (B,) when batch_axis="failure"
    config: SimConfig,
    reg_names: tuple,
    keep_traces: bool,
    batch_axis: str | None,
):
    """The trace-based (policy × scenario) grid kernel — the parity oracle.

    Materializes a full ``SimTrace`` per cell (and vmaps the policy axis, so
    the per-step ``lax.switch`` lowers to evaluate-all-branches: P² policy
    evaluations per grid).  ``keep_traces=True`` sweeps and
    ``stream=False`` parity checks run here; the streaming kernel
    (``_stream_grid_jit``) is the default hot path.

    ``batch_axis`` picks the outermost vmapped dimension: None (plain
    ``sweep``), "fleet" (batched fleet leaves + matched per-fleet arrival
    columns), "workflow" (batched routing topologies over one shared
    scenario block), "capacity" (batched warm-pool autoscaler configs), or
    "failure" (stacked chaos scenarios over one shared workload block).
    """

    def cell(fl, wf, cp, fs, pid, arr):
        trace = simulate_core(
            pid, arr, fl, config, reg_names, wf, cp, failures=fs
        )
        vec, per_lat, per_tput, per_q = trace_metrics(
            trace, fl.active, wf, config=config
        )
        if keep_traces:
            return vec, per_lat, per_tput, per_q, trace
        return vec, per_lat, per_tput, per_q

    over_scen = jax.vmap(cell, in_axes=(None, None, None, None, None, 0))
    over_pol = jax.vmap(over_scen, in_axes=(None, None, None, None, 0, None))
    if batch_axis is None:
        return over_pol(fleet, workflow, capacity, fspec, pids, arrivals)
    outer_axes = {
        "fleet": (0, None, None, None, None, 0),
        "workflow": (None, 0, None, None, None, None),
        "capacity": (None, None, 0, None, None, None),
        "failure": (None, None, None, 0, None, None),
    }[batch_axis]
    return jax.vmap(over_pol, in_axes=outer_axes)(
        fleet, workflow, capacity, fspec, pids, arrivals
    )


def synth_gen_groups(wspec) -> tuple | None:
    """Partition a stacked spec's scenario axis by generator, statically.

    Returns ``((gen_name, (idx, ...)), ...)`` covering every scenario
    column, or ``None`` when grouping does not apply (no spec, or a
    fleet-batched stack whose scenario columns change generator across
    fleets).  Must be called *outside* jit — it reads concrete ``gen_id``
    values.

    The payoff: ``_stream_grid`` vmaps each group separately with the
    generator name passed statically, so synthesis dispatches directly
    instead of through the vmapped ``lax.switch``, whose
    evaluate-all-branches lowering makes every scenario column pay every
    registered generator per step — the poisson sampler alone was measured
    at ~93% of all-branches block synthesis cost while typically only one
    column actually runs it.
    """
    if wspec is None:
        return None
    gids = np.asarray(wspec.gen_id)
    if gids.ndim == 2:
        # (F, W) fleet-batched stack: grouping needs one generator per
        # scenario column across every fleet row.
        if not (gids == gids[0]).all():
            return None
        gids = gids[0]
    names = workload.workload_names()
    groups: dict[int, list[int]] = {}
    for i, gid in enumerate(gids.tolist()):
        groups.setdefault(int(gid), []).append(i)
    return tuple((names[gid], tuple(idx)) for gid, idx in groups.items())


def _stream_grid(
    arrivals: jnp.ndarray | None,  # (W, S, N), or (F, W, S, N) when batch_axis="fleet"
    fleet: Fleet,            # leaves (N,), or (F, N) when batch_axis="fleet"
    workflow: Workflow | None,  # leaves (K, N, N)/(K, N) when batch_axis="workflow"
    capacity: CapacityConfig | None,  # leaves (C,) when batch_axis="capacity"
    wspec=None,              # stacked WorkloadSpec, leaves (W, ·)/(F, W, ·)
    fspec=None,              # FailureSpec | None; leaves (B,) when batch_axis="failure"
    config: SimConfig = None,
    names: tuple = (),
    batch_axis: str | None = None,
    num_policy_blocks: int = 1,
    block_size: int = 1,
    gen_groups: tuple | None = None,
):
    """The streaming (policy × scenario) grid kernel — the default for
    ``keep_traces=False`` sweeps.

    Each cell runs ``simulate_stream_core``: the whole policy axis in ONE
    scan (O(P) dispatch via the unrolled ``alloc.policy_stack`` instead of
    the vmapped ``lax.switch``'s P² evaluate-all-branches lowering) with
    metrics accumulated in the carry (peak memory per cell O(P · N), not
    O(P · S · N)).  Only the scenario axis — and the optional outer
    fleet/workflow/capacity axis — is vmapped.  ``_grid_jit`` remains the
    trace-materializing parity oracle.

    The workload column is EITHER a materialized arrivals tensor OR a
    stacked ``WorkloadSpec`` (``wspec``), never both: with a spec each
    cell's arrival rows are synthesized *inside* the scan
    (``workload_step``), so nothing of shape (S, ·) exists on the input
    side either.  With ``num_policy_blocks`` > 1 the kernel runs under the
    3D mesh and computes only this device's policy block, selected by
    ``lax.axis_index("policy")`` (``allocator.policy_stack_blocks``).

    ``gen_groups`` (static; build with ``synth_gen_groups``) partitions the
    scenario axis by generator so each group's synthesis dispatches its
    generator *directly* instead of through the vmapped ``lax.switch`` —
    the single-device synth fast path.  The sharded placement keeps the
    switch (``gen_groups=None``): ``shard_map`` needs one uniform program
    whatever scenario columns land on a device.

    This function is deliberately unjitted: ``_stream_grid_jit`` wraps it
    for the single-device path and ``_stream_grid_sharded`` runs the exact
    same body per device block under ``shard_map`` — one kernel, two
    placements, no way for the sharded math to drift.
    """
    block = (
        jax.lax.axis_index(sharding.POLICY_AXIS)
        if num_policy_blocks > 1 else None
    )

    def cell(arr, fl, wf, cp, sp, fs, gen_name=None):
        return simulate_stream_core(
            arr, fl, config, names, wf, cp, workload_spec=sp,
            num_policy_blocks=num_policy_blocks, policy_block=block,
            block_size=block_size, gen_name=gen_name, failures=fs,
        )

    a_ax = None if arrivals is None else 0
    s_ax = None if wspec is None else 0

    # out_axes=1: the per-cell policy axis stays leading, scenarios second,
    # matching the trace kernel's (…, P, W, ·) layout.
    def over_scen(arr, fl, wf, cp, sp, fs):
        if gen_groups is None or sp is None:
            return jax.vmap(
                cell, in_axes=(a_ax, None, None, None, s_ax, None),
                out_axes=1,
            )(arr, fl, wf, cp, sp, fs)
        # Grouped static dispatch (``synth_gen_groups``): one vmap per
        # generator group, each synthesizing through its generator
        # directly — no vmapped ``lax.switch``, so no
        # evaluate-all-branches blowup where every scenario column pays
        # every registered sampler.  Outputs are reassembled in the
        # caller's scenario order by a static inverse permutation;
        # per-cell results are bit-identical to the switch path.
        outs, order = [], []
        for gname, idx in gen_groups:
            sub = jax.tree_util.tree_map(
                lambda x, i=np.asarray(idx): x[i], sp
            )
            outs.append(jax.vmap(
                functools.partial(cell, gen_name=gname),
                in_axes=(None, None, None, None, 0, None), out_axes=1,
            )(None, fl, wf, cp, sub, fs))
            order.extend(idx)
        inv = np.argsort(np.asarray(order))
        return jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=1)[:, inv], *outs
        )

    if batch_axis is None:
        return over_scen(arrivals, fleet, workflow, capacity, wspec, fspec)
    outer_axes = {
        "fleet": (a_ax, 0, None, None, s_ax, None),
        "workflow": (None, None, 0, None, None, None),
        "capacity": (None, None, None, 0, None, None),
        "failure": (None, None, None, None, None, 0),
    }[batch_axis]
    return jax.vmap(over_scen, in_axes=outer_axes)(
        arrivals, fleet, workflow, capacity, wspec, fspec
    )


_stream_grid_jit = functools.partial(
    jax.jit,
    static_argnames=(
        "config", "names", "batch_axis", "num_policy_blocks", "block_size",
        "gen_groups",
    ),
)(_stream_grid)


@functools.partial(
    jax.jit,
    static_argnames=(
        "mesh", "config", "names", "batch_axis", "num_policy_blocks",
        "block_size",
    ),
    donate_argnums=(0,),
)
def _stream_grid_sharded(
    arrivals: jnp.ndarray | None,
    fleet: Fleet,
    workflow: Workflow | None,
    capacity: CapacityConfig | None,
    wspec,
    fspec,
    mesh: jax.sharding.Mesh,
    config: SimConfig,
    names: tuple,
    batch_axis: str | None,
    num_policy_blocks: int = 1,
    block_size: int = 1,
):
    """The sharded streaming grid: ``shard_map`` of ``_stream_grid`` over
    the ``("data", "grid", "policy")`` mesh.

    Each device runs the unchanged per-cell streaming scan on its
    (batch-block × scenario-block) of the grid — cells are independent, so
    no collectives appear anywhere in the body.  ``arrivals`` (the grid's
    dominant input, (F, W, S, N) floats) is **donated**: XLA may reuse its
    buffer for outputs/scratch instead of double-buffering million-cell
    grids.  Callers must therefore pass a freshly built (or freshly
    padded) array and never reuse it afterwards — every sweep entry point
    rebuilds arrivals per call, which is what keeps second calls safe
    (tests/test_sharding.py).  A synthesized grid (``wspec`` instead of
    ``arrivals``) has no slab to donate — its dominant input is O(W · N).

    With ``num_policy_blocks`` > 1 the policy dim of every output shards
    over the mesh's third axis: each device evaluates only its own block
    of policy rows (inputs stay replicated along ``policy`` — every block
    reads the same state).  The default ``dp=1`` path never consults the
    axis, so it lowers to the exact 2D program.

    Axes must already divide the mesh (``_run_grid`` pads them); specs are
    built in ``core/sharding.py::grid_specs``.
    """
    in_specs, out_spec = sharding.grid_specs(
        batch_axis, policy=num_policy_blocks > 1
    )
    body = functools.partial(
        _stream_grid, config=config, names=names, batch_axis=batch_axis,
        num_policy_blocks=num_policy_blocks, block_size=block_size,
    )
    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
        check_rep=False,
    )(arrivals, fleet, workflow, capacity, wspec, fspec)


def _run_stream_sharded(
    arrivals: jnp.ndarray | None,
    fleet: Fleet,
    workflow: Workflow | None,
    capacity: CapacityConfig | None,
    config: SimConfig,
    names: tuple,
    batch_axis: str | None,
    wspec=None,
    fspec=None,
    policy_devices: int = 1,
    block_size: int = 1,
):
    """Pad the sharded axes to mesh divisibility, run the sharded streaming
    kernel, strip the padding host-side.

    Padding repeats row 0 (always-valid cells — the ``active``-mask idiom
    of inert-but-well-posed filler) instead of falling back to whole-axis
    replication, so a non-divisible axis costs at most ``mesh_dim - 1``
    wasted rows rather than ``device_count - 1`` redundant copies of the
    entire grid.  The stripped results are identical to the unpadded grid
    because cells never interact.  A stacked ``WorkloadSpec`` pads exactly
    like the arrivals tensor it replaces (same leading axes, O(N) rows).
    With ``policy_devices`` (dp) > 1 the *name list* pads the same way —
    repeating ``names[0]`` up to divisibility, stripped from the output's
    policy dim — and the kernel dispatches per-device policy blocks.
    """
    dp = int(policy_devices)
    mesh = sharding.grid_mesh(policy_devices=dp)
    dd = mesh.shape[sharding.DATA_AXIS]
    dg = mesh.shape[sharding.GRID_AXIS]
    p = len(names)
    if dp > 1:
        names = tuple(names) + (names[0],) * ((-p) % dp)

    def pad(axis_mults):
        nonlocal arrivals, wspec
        for axis, mult in axis_mults:
            if arrivals is not None:
                arrivals = sharding.pad_axis(arrivals, axis, mult)
            else:
                wspec = sharding.pad_tree_axis(wspec, axis, mult)

    if batch_axis is None:
        w = arrivals.shape[0] if wspec is None else wspec.gen_id.shape[0]
        pad([(0, dd * dg)])
        out = _stream_grid_sharded(
            arrivals, fleet, workflow, capacity, wspec, fspec, mesh, config,
            names, batch_axis, dp, block_size,
        )
        return tuple(x[:p, :w] for x in out)
    if batch_axis == "fleet":
        b, w = (
            arrivals.shape[:2] if wspec is None else wspec.gen_id.shape[:2]
        )
        pad([(0, dd), (1, dg)])
        fleet = sharding.pad_tree_axis(fleet, 0, dd)
    elif batch_axis == "workflow":
        b = workflow.route.shape[0]
        w = arrivals.shape[0] if wspec is None else wspec.gen_id.shape[0]
        pad([(0, dg)])
        workflow = sharding.pad_tree_axis(workflow, 0, dd)
    elif batch_axis == "failure":
        b = fspec.revoke_frac.shape[0]
        w = arrivals.shape[0] if wspec is None else wspec.gen_id.shape[0]
        pad([(0, dg)])
        fspec = sharding.pad_tree_axis(fspec, 0, dd)
    else:
        b = capacity.policy_id.shape[0]
        w = arrivals.shape[0] if wspec is None else wspec.gen_id.shape[0]
        pad([(0, dg)])
        capacity = sharding.pad_tree_axis(capacity, 0, dd)
    out = _stream_grid_sharded(
        arrivals, fleet, workflow, capacity, wspec, fspec, mesh, config,
        names, batch_axis, dp, block_size,
    )
    return tuple(x[:b, :p, :w] for x in out)


def _run_grid(
    pids: jnp.ndarray,
    arrivals: jnp.ndarray | None,
    fleet: Fleet,
    workflow: Workflow | None,
    capacity: CapacityConfig | None,
    config: SimConfig,
    reg_names: tuple,
    names: tuple,
    keep_traces: bool,
    stream: bool | None,
    batch_axis: str | None,
    shard: bool | None = None,
    wspec=None,
    fspec=None,
    block_size: int | None = None,
):
    """Pick the kernel and placement for one sweep call: streaming by
    default — sharded over the ``("data", "grid", "policy")`` mesh whenever
    more than one device is live (``sharding.should_shard``; the policy
    axis only splits when requested, ``sharding.policy_mesh_devices``) —
    and the trace-based oracle when traces are requested or
    ``stream=False``.  The workload column arrives EITHER materialized
    (``arrivals``) or as a stacked ``WorkloadSpec`` (``wspec``) for in-scan
    synthesis; the entry points materialize specs host-side before any
    non-streaming call, so the trace oracle only ever sees tensors.

    Returns the kernel's device-array tuple — (metrics, per-lat, per-tput,
    per-queue[, traces]).
    """
    streamed = (not keep_traces) if stream is None else bool(stream)
    if streamed and keep_traces:
        raise ValueError(
            "streaming mode accumulates metrics in O(1) memory per step and "
            "never materializes traces; use keep_traces=True with "
            "stream=False (or leave stream unset)"
        )
    if wspec is not None and not streamed:
        raise ValueError(
            "in-scan synthesis runs inside the streaming kernel; "
            "materialize the specs for the trace oracle"
        )
    sharded = sharding.should_shard(shard)
    if streamed:
        # Resolved here — before any jit boundary — so the env default is
        # read exactly once per call and B enters the kernels static.
        bsz = resolve_block_size(block_size)
        if sharded:
            return _run_stream_sharded(
                arrivals, fleet, workflow, capacity, config, names,
                batch_axis, wspec=wspec, fspec=fspec,
                policy_devices=sharding.policy_mesh_devices(shard),
                block_size=bsz,
            )
        return _stream_grid_jit(
            arrivals, fleet, workflow, capacity, wspec, fspec, config,
            names, batch_axis, block_size=bsz,
            gen_groups=synth_gen_groups(wspec),
        )
    if sharded and batch_axis == "fleet":
        # The parity oracle keeps the pre-shard_map layout-hint path: pad
        # the fleet axis to device divisibility (never replicate — the old
        # fallback burned device_count× redundant work), lay it across the
        # flattened mesh, and strip the padded rows from every output
        # (traces included) host-side.
        f = arrivals.shape[0]
        fleet, arrivals = _shard_fleet_axis(fleet, arrivals)
        out = _grid_jit(
            pids, arrivals, fleet, workflow, capacity, fspec, config,
            reg_names, keep_traces, batch_axis,
        )
        return tuple(
            jax.tree_util.tree_map(lambda x: x[:f], o) for o in out
        )
    return _grid_jit(
        pids, arrivals, fleet, workflow, capacity, fspec, config, reg_names,
        keep_traces, batch_axis,
    )


def _shard_fleet_axis(stacked: Fleet, arrivals: jnp.ndarray, mesh=None):
    """Lay the fleet axis of the trace-oracle grid across every device.

    A ``NamedSharding`` layout *hint* (GSPMD propagates it through the
    vmapped kernel) over the flattened 2D mesh.  A fleet count that does
    not divide the device count is **padded** to the next multiple with
    copies of fleet 0 — the old whole-axis replication fallback silently
    forfeited all parallelism (6 fleets on 4 devices ran every cell on
    every device); padded rows cost at most ``device_count - 1`` wasted
    fleets and are stripped by ``_run_grid``, keeping metrics identical.
    """
    mesh = sharding.grid_mesh() if mesh is None else mesh
    total = int(np.prod(list(mesh.shape.values())))
    stacked = sharding.pad_tree_axis(stacked, 0, total)
    arrivals = sharding.pad_axis(arrivals, 0, total)
    spec = PartitionSpec((sharding.DATA_AXIS, sharding.GRID_AXIS))
    layout = NamedSharding(mesh, spec)
    return jax.device_put(stacked, layout), jax.device_put(arrivals, layout)


def _prepare_scenarios(
    scenarios, synthesize: bool | None, streamed: bool
) -> tuple[tuple[str, ...], jnp.ndarray | None, "workload.WorkloadSpec | None"]:
    """Resolve one sweep call's workload column: (names, arrivals, wspec).

    ``scenarios`` is a homogeneous list of either ``Scenario`` tensors (the
    classic path — ``synthesize`` must stay unset/False) or
    ``workload.WorkloadSpec`` rows.  Specs run **in-scan** (``wspec``
    returned, ``arrivals=None``) when synthesis is on — the default for
    specs — AND the call streams AND the ``REPRO_SWEEP_SYNTH`` hatch is not
    "0"; otherwise they are materialized host-side via
    ``workload.materialize``, which scans the very same registered step
    functions, so both arms are bit-for-bit identical by construction
    (the acceptance contract, tests/test_workload_synthesis.py).
    """
    scenarios = list(scenarios)
    if not scenarios:
        raise ValueError("need at least one scenario")
    spec_like = [isinstance(s, workload.WorkloadSpec) for s in scenarios]
    names = tuple(s.name for s in scenarios)
    if any(spec_like):
        if not all(spec_like):
            raise ValueError(
                "scenarios must be all Scenario or all WorkloadSpec, not a mix"
            )
        synth = True if synthesize is None else bool(synthesize)
        if synth and streamed and workload.synth_env_enabled():
            return names, None, workload.stack_specs(scenarios)
        return names, jnp.stack(
            [workload.materialize(s) for s in scenarios]
        ), None
    if synthesize:
        raise ValueError(
            "synthesize=True needs WorkloadSpec scenarios "
            "(e.g. workload.scenario_specs); got materialized Scenario tensors"
        )
    return names, jnp.stack(
        [jnp.asarray(s.arrivals, jnp.float32) for s in scenarios]
    ), None


def _streamed(keep_traces: bool, stream: bool | None) -> bool:
    return (not keep_traces) if stream is None else bool(stream)


def _resolve_failure_axis(failures, allow_batch: bool):
    """Resolve one sweep call's ``failures=`` argument.

    Returns ``(fspec, failure_names)``: a single validated spec (or None)
    with no axis, or — on the plain ``sweep`` only (``allow_batch``) — a
    stacked spec plus its scenario names, the vmapped chaos axis.  The
    ``REPRO_FAILURES=0`` kill switch applies before anything else.
    """
    if isinstance(failures, fail_mod.FailureSpec) or failures is None:
        failures = fail_mod.resolve_failures(failures)
        if failures is None:
            return None, None
        if failures.batched:
            raise ValueError(
                "pass a sequence of FailureSpec rows (not a pre-stacked "
                "spec) to put failures on the sweep axis"
            )
        fail_mod.check_failures(failures)
        return failures, None
    specs = list(failures)
    if not allow_batch:
        raise ValueError(
            "only the plain sweep() supports a failure axis; "
            "sweep_fleets/sweep_workflows/sweep_capacity already batch "
            "their own axis — pass a single FailureSpec"
        )
    if not specs:
        raise ValueError("need at least one failure scenario")
    for s in specs:
        fail_mod.check_failures(s)
    if not fail_mod.failures_env_enabled():
        return None, None
    return fail_mod.stack_failures(specs), tuple(s.name for s in specs)


def sweep(
    fleet: Fleet,
    scenarios: Sequence[Scenario],
    config: SimConfig = SimConfig(),
    policies: Sequence[str] | None = None,
    keep_traces: bool = False,
    capacity: CapacityConfig | None = None,
    stream: bool | None = None,
    return_arrays: bool = False,
    shard: bool | None = None,
    synthesize: bool | None = None,
    block_size: int | None = None,
    failures=None,
) -> SweepResult | tuple:
    """Evaluate ``policies`` (default: the whole registry) × ``scenarios``.

    All scenarios must share one (S, N) shape.  The grid is a single jitted
    call (cached across calls with the same fleet structure/config/
    registry): by default the **streaming kernel** (``_stream_grid_jit`` —
    O(P) policy dispatch, metrics accumulated in the scan carry so peak
    memory per cell never grows with the horizon); ``keep_traces=True`` or
    ``stream=False`` selects the trace-materializing oracle kernel.  An
    optional ``capacity`` autoscaler applies to every cell; cost is a
    per-cell metric either way.  ``return_arrays=True`` skips the host
    transfer and returns the kernel's raw device arrays — the benchmark
    timing surface (``jax.block_until_ready`` them to time device work).
    On a multi-device host the scenario axis of the streaming grid shards
    over the full (data × grid) mesh plane (``core/sharding.py``);
    ``shard=False`` — or ``REPRO_SWEEP_SHARD=0`` in the environment —
    forces the single-device path, and ``shard="3d"`` additionally splits
    the policy axis over the mesh's third dimension.

    ``scenarios`` may be ``workload.WorkloadSpec`` rows instead of
    materialized ``Scenario`` tensors: by default (``synthesize=None`` or
    ``True``) their arrival rows are then synthesized *inside* the scan —
    the input side never materializes an (S, N) slab, making S = 10⁶⁺
    horizons feasible.  ``synthesize=False`` (or ``REPRO_SWEEP_SYNTH=0``,
    or any trace-oracle run) materializes the same specs host-side via the
    same registered step functions — bit-for-bit identical results, the
    synthesis parity oracle.

    ``block_size`` (or ``REPRO_SWEEP_BLOCK``; default 1) sets the
    streaming kernel's time-block B: the scan walks the horizon in
    B-step blocks — one workload dispatch and one capped-unroll inner
    scan per block — identical results at every B, trading one-time
    compile cost for steady-state throughput (see
    ``simulate_stream_core``).  The same knob threads through every
    sweep entry point, sharded or not.

    ``failures`` injects chaos (``core/failures.py``): a single
    ``FailureSpec`` applies to every cell, while a *sequence* of specs
    (e.g. ``failure_scenario_library()``) becomes a vmapped **failure
    axis** — the grid grows a leading chaos dimension exactly like the
    fleet/workflow/capacity axes of the other entry points, and the
    result carries ``failure_names``.  ``failures=None`` is bit-for-bit
    the pre-failure program; ``REPRO_FAILURES=0`` forces that path.
    """
    fleet.validate()
    if capacity is not None:
        check_capacity(capacity, config.g_total, config.num_gpus)
    fspec, failure_names = _resolve_failure_axis(failures, allow_batch=True)
    batch_axis = None if failure_names is None else "failure"
    reg_names = alloc.policy_names()
    names = reg_names if policies is None else tuple(policies)
    pids = jnp.asarray([alloc.policy_id(p) for p in names])
    scen_names, arrivals, wspec = _prepare_scenarios(
        scenarios, synthesize, _streamed(keep_traces, stream)
    )  # (W, S, N) | stacked spec

    out = _run_grid(pids, arrivals, fleet, None, capacity, config,
                       reg_names, names, keep_traces, stream, batch_axis,
                       shard, wspec=wspec, fspec=fspec,
                       block_size=block_size)
    if return_arrays:
        return out
    metrics, per_lat, per_tput, per_q = (np.asarray(x) for x in out[:4])
    traces = out[4] if keep_traces else None

    return SweepResult(
        policy_names=names,
        scenario_names=scen_names,
        metrics=metrics,
        per_agent_latency=per_lat,
        per_agent_throughput=per_tput,
        config=config,
        traces=traces,
        per_agent_queue=per_q,
        failure_names=failure_names,
    )


def sweep_fleets(
    fleets: Sequence[Fleet],
    rate_vectors: Sequence[Sequence[float] | jnp.ndarray] | None = None,
    num_steps: int = 100,
    seed: int = 0,
    config: SimConfig = SimConfig(),
    policies: Sequence[str] | None = None,
    fleet_names: Sequence[str] | None = None,
    keep_traces: bool = False,
    shard: bool | None = True,
    stream: bool | None = None,
    return_arrays: bool = False,
    synthesize: bool | None = None,
    block_size: int | None = None,
    failures=None,
) -> SweepResult | tuple:
    """One jitted (fleet × policy × scenario) grid over heterogeneous fleets.

    Fleets are padded to the widest member and stacked into a single batched
    ``Fleet`` pytree; each fleet gets a matched scenario column generated at
    its true size from its own rate vector (default:
    ``workload.synthetic_rates`` at the paper's aggregate load, so total
    demand is held constant while the agent count scales).  With
    ``shard=True`` (the default) a multi-device host lays the fleet axis
    over the 2D mesh's ``data`` axis and the scenario axis over its
    ``grid`` axis via ``shard_map`` (trace-oracle runs keep a
    ``NamedSharding`` hint on the fleet axis); non-divisible axes are
    padded, never replicated, and single-device metrics are bit-identical
    to the unsharded kernel.  ``shard=False`` or ``REPRO_SWEEP_SHARD=0``
    forces the single-device path.  The per-fleet rows match the unbatched
    ``sweep`` within float tolerance.  The streaming kernel (default for
    ``keep_traces=False``) is what makes the long-horizon end of this grid
    feasible at all: peak memory per cell is O(N), not O(S · N), so
    N = 1024 fleets over 10⁴-step horizons fit on a single host.

    ``synthesize`` selects the workload column's representation:
    ``None`` (default) keeps the legacy materialized
    ``fleet_scenario_library`` tensors; ``True`` builds the matched
    per-fleet ``workload.fleet_scenario_specs`` and synthesizes arrivals
    *in-scan* (no (F, W, S, N) slab is ever built — the horizon-frontier
    mode); ``False`` materializes those same specs host-side (the
    synthesis parity arm, bit-identical to ``True`` by construction).
    """
    fleets = list(fleets)
    if not fleets:
        raise ValueError("sweep_fleets needs at least one fleet")
    for f in fleets:
        f.validate()
    if rate_vectors is None:
        rate_vectors = [
            workload.synthetic_rates(f.num_agents, seed=seed + i)
            for i, f in enumerate(fleets)
        ]
    if len(rate_vectors) != len(fleets):
        raise ValueError("need one rate vector per fleet")
    for i, (f, r) in enumerate(zip(fleets, rate_vectors)):
        width = np.asarray(r).shape[-1]
        if width != f.num_agents:
            raise ValueError(
                f"rate vector {i} has {width} agents but fleet {i} has "
                f"{f.num_agents}; a mismatch would silently zero real demand"
            )
    if fleet_names is None:
        fleet_names = tuple(f"fleet{i}_n{f.num_agents}" for i, f in enumerate(fleets))
    else:
        fleet_names = tuple(fleet_names)

    stacked = stack_fleets(fleets)
    wspec = None
    if synthesize is None:
        scen_names, arrivals = fleet_scenario_library(
            rate_vectors, stacked.num_agents, num_steps, seed
        )  # (F, W, S, N_max)
    else:
        scen_names, spec_rows = workload.fleet_scenario_specs(
            rate_vectors, stacked.num_agents, num_steps, seed
        )
        cols = [
            workload.stack_specs(row, name=f"fleet{i}")
            for i, row in enumerate(spec_rows)
        ]
        if (synthesize and _streamed(keep_traces, stream)
                and workload.synth_env_enabled()):
            arrivals = None
            wspec = workload.stack_specs(cols, name="fleet_grid")
        else:
            arrivals = jnp.stack([
                jnp.stack([workload.materialize(s) for s in row])
                for row in spec_rows
            ])  # the parity arm: same step functions, host-scanned

    fspec, _ = _resolve_failure_axis(failures, allow_batch=False)
    reg_names = alloc.policy_names()
    names = reg_names if policies is None else tuple(policies)
    pids = jnp.asarray([alloc.policy_id(p) for p in names])

    out = _run_grid(pids, arrivals, stacked, None, None, config,
                       reg_names, names, keep_traces, stream, "fleet", shard,
                       wspec=wspec, fspec=fspec, block_size=block_size)
    if return_arrays:
        return out
    metrics, per_lat, per_tput, per_q = (np.asarray(x) for x in out[:4])
    traces = out[4] if keep_traces else None

    return SweepResult(
        policy_names=names,
        scenario_names=scen_names,
        metrics=metrics,
        per_agent_latency=per_lat,
        per_agent_throughput=per_tput,
        config=config,
        traces=traces,
        fleet_names=fleet_names,
        per_agent_queue=per_q,
    )


def workflow_scenario_library(
    num_agents: int, seed: int = 0, fan_out: float = 1.0
) -> tuple[Workflow, ...]:
    """The canonical workflow-topology set for one fleet width.

    ``independent`` (today's exogenous behavior), ``coordinator_star``,
    ``pipeline_chain``, ``hierarchical`` (when the width allows it) and a
    reproducible random DAG.  The workflow axis of ``sweep_workflows``.
    """
    wfs = [routing.independent(num_agents)]
    if num_agents >= 2:
        wfs.append(routing.coordinator_star(num_agents, fan_out=fan_out))
        wfs.append(routing.pipeline_chain(num_agents))
    if num_agents >= 3:
        wfs.append(routing.hierarchical(num_agents, fan_out=fan_out))
    wfs.append(routing.synthetic_workflow(num_agents, seed=seed))
    return tuple(wfs)


def sweep_workflows(
    fleet: Fleet,
    workflows: Sequence[Workflow] | None = None,
    scenarios: Sequence[Scenario] | None = None,
    num_steps: int = 100,
    seed: int = 0,
    config: SimConfig = SimConfig(),
    policies: Sequence[str] | None = None,
    keep_traces: bool = False,
    stream: bool | None = None,
    return_arrays: bool = False,
    shard: bool | None = None,
    synthesize: bool | None = None,
    block_size: int | None = None,
    failures=None,
) -> SweepResult | tuple:
    """One jitted (workflow × policy × scenario) grid over one fleet.

    Every workflow must already span the fleet's width (``pad_workflow`` a
    narrower topology explicitly); they are stacked into a single batched
    ``Workflow`` pytree (``stack_workflows``).  The same scenario block
    feeds every topology — the simulator gates exogenous arrivals by each
    workflow's source flags, so a coordinator-star column only injects
    traffic at the coordinator.  Defaults: the canonical topology library
    at the fleet's width, and the standard scenario library over
    ``workload.synthetic_rates``.  On a multi-device host the workflow
    axis shards over the mesh's ``data`` axis and the scenario axis over
    ``grid`` (``shard=False`` / ``REPRO_SWEEP_SHARD=0`` force the
    single-device path).
    """
    fleet.validate()
    n = fleet.num_agents
    if workflows is None:
        workflows = workflow_scenario_library(n, seed=seed)
    workflows = list(workflows)
    if not workflows:
        raise ValueError("sweep_workflows needs at least one workflow")
    for wf in workflows:
        routing.check_workflow(wf, n)
    workflow_names = tuple(w.name for w in workflows)
    if len(set(workflow_names)) != len(workflow_names):
        raise ValueError(f"workflow names must be unique: {workflow_names}")
    stacked_wf = stack_workflows(workflows)  # all widths == n after the check

    if scenarios is None:
        rates = workload.synthetic_rates(n, seed=seed)
        scenarios = (
            workload.scenario_specs(rates, num_steps, seed) if synthesize
            else scenario_library(rates, num_steps, seed)
        )
    scen_names, arrivals, wspec = _prepare_scenarios(
        scenarios, synthesize, _streamed(keep_traces, stream)
    )  # (W, S, N) | stacked spec

    fspec, _ = _resolve_failure_axis(failures, allow_batch=False)
    reg_names = alloc.policy_names()
    names = reg_names if policies is None else tuple(policies)
    pids = jnp.asarray([alloc.policy_id(p) for p in names])

    out = _run_grid(pids, arrivals, fleet, stacked_wf, None, config,
                       reg_names, names, keep_traces, stream, "workflow",
                       shard, wspec=wspec, fspec=fspec,
                       block_size=block_size)
    if return_arrays:
        return out
    metrics, per_lat, per_tput, per_q = (np.asarray(x) for x in out[:4])
    traces = out[4] if keep_traces else None

    return SweepResult(
        policy_names=names,
        scenario_names=scen_names,
        metrics=metrics,
        per_agent_latency=per_lat,
        per_agent_throughput=per_tput,
        config=config,
        traces=traces,
        workflow_names=workflow_names,
        per_agent_queue=per_q,
    )


def capacity_scenario_library(
    cold_start_s: float = 5.0,
    keep_alive_s: float = 10.0,
    target_rate_per_instance: float = 60.0,
    backlog_per_instance: float = 50.0,
) -> tuple[CapacityConfig, ...]:
    """The canonical capacity-policy set — the capacity axis of
    ``sweep_capacity``.

    ``fixed`` (the pre-capacity always-on pool), ``reactive`` with free
    scale-up, ``reactive_cold`` paying ``cold_start_s`` per new instance,
    and ``scale_to_zero`` with both a cold start and a keep-alive window.
    """
    return (
        capacity_config("fixed"),
        capacity_config(
            "reactive",
            target_rate_per_instance=target_rate_per_instance,
            backlog_per_instance=backlog_per_instance,
            min_instances=1.0,
        ),
        capacity_config(
            "reactive",
            cold_start_s=cold_start_s,
            target_rate_per_instance=target_rate_per_instance,
            backlog_per_instance=backlog_per_instance,
            min_instances=1.0,
            name="reactive_cold",
        ),
        capacity_config(
            "scale_to_zero",
            cold_start_s=cold_start_s,
            keep_alive_s=keep_alive_s,
            target_rate_per_instance=target_rate_per_instance,
            backlog_per_instance=backlog_per_instance,
        ),
    )


def sweep_capacity(
    fleet: Fleet,
    capacities: Sequence[CapacityConfig] | None = None,
    scenarios: Sequence[Scenario] | None = None,
    num_steps: int = 100,
    seed: int = 0,
    config: SimConfig = SimConfig(),
    policies: Sequence[str] | None = None,
    keep_traces: bool = False,
    stream: bool | None = None,
    return_arrays: bool = False,
    shard: bool | None = None,
    synthesize: bool | None = None,
    block_size: int | None = None,
    failures=None,
) -> SweepResult | tuple:
    """One jitted (capacity × policy × scenario) grid over one fleet.

    Capacity configs are stacked into a single batched ``CapacityConfig``
    pytree (``stack_capacities``) and vmapped outermost over the same
    ``_grid_jit`` kernel as every other sweep — allocation policies are
    ranked under *elasticity regimes*, and because billing is
    warm-instance-seconds the grid's cost column differs across allocation
    policies, capacity policies, and scenarios (the paper's cost-efficiency
    comparison, finally non-vacuous).  Defaults: the canonical capacity
    library and the standard scenario library over
    ``workload.synthetic_rates``.  On a multi-device host the capacity
    axis shards over the mesh's ``data`` axis and the scenario axis over
    ``grid`` (``shard=False`` / ``REPRO_SWEEP_SHARD=0`` force the
    single-device path).
    """
    fleet.validate()
    if capacities is None:
        capacities = capacity_scenario_library()
    capacities = list(capacities)
    if not capacities:
        raise ValueError("sweep_capacity needs at least one capacity config")
    for cp in capacities:
        check_capacity(cp, config.g_total, config.num_gpus)
    capacity_names = tuple(c.name for c in capacities)
    if len(set(capacity_names)) != len(capacity_names):
        raise ValueError(f"capacity names must be unique: {capacity_names}")
    stacked_cap = stack_capacities(capacities)

    if scenarios is None:
        rates = workload.synthetic_rates(fleet.num_agents, seed=seed)
        scenarios = (
            workload.scenario_specs(rates, num_steps, seed) if synthesize
            else scenario_library(rates, num_steps, seed)
        )
    scen_names, arrivals, wspec = _prepare_scenarios(
        scenarios, synthesize, _streamed(keep_traces, stream)
    )  # (W, S, N) | stacked spec

    fspec, _ = _resolve_failure_axis(failures, allow_batch=False)
    reg_names = alloc.policy_names()
    names = reg_names if policies is None else tuple(policies)
    pids = jnp.asarray([alloc.policy_id(p) for p in names])

    out = _run_grid(pids, arrivals, fleet, None, stacked_cap, config,
                       reg_names, names, keep_traces, stream, "capacity",
                       shard, wspec=wspec, fspec=fspec,
                       block_size=block_size)
    if return_arrays:
        return out
    metrics, per_lat, per_tput, per_q = (np.asarray(x) for x in out[:4])
    traces = out[4] if keep_traces else None

    return SweepResult(
        policy_names=names,
        scenario_names=scen_names,
        metrics=metrics,
        per_agent_latency=per_lat,
        per_agent_throughput=per_tput,
        config=config,
        traces=traces,
        capacity_names=capacity_names,
        per_agent_queue=per_q,
    )
