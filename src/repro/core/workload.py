"""Workload (arrival-process) generators for the fleet simulator.

The paper simulates 100 one-second steps with fixed per-agent arrival rates
(80/40/45/25 rps) and a fixed random seed.  Constant arrivals reproduce
Table II exactly; Poisson, spike, diurnal and domination processes support
the robustness study (§V-B) and beyond-paper experiments.

Every generator returns an (S, N) float32 array of arrivals per step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def constant(rates: jnp.ndarray, num_steps: int) -> jnp.ndarray:
    """lam_i(t) = rates_i for all t (reproduces the paper's Table II)."""
    rates = jnp.asarray(rates, jnp.float32)
    return jnp.broadcast_to(rates, (num_steps, rates.shape[0]))


def poisson(rates: jnp.ndarray, num_steps: int, key: jax.Array) -> jnp.ndarray:
    """Poisson(lam_i) arrivals per step, fixed seed for reproducibility."""
    rates = jnp.asarray(rates, jnp.float32)
    draws = jax.random.poisson(key, rates, shape=(num_steps, rates.shape[0]))
    return draws.astype(jnp.float32)


def spike(
    rates: jnp.ndarray,
    num_steps: int,
    spike_agent: int,
    spike_start: int,
    spike_len: int,
    magnitude: float = 10.0,
) -> jnp.ndarray:
    """10x arrival-rate spike on one agent (§V-B adaptation-speed test)."""
    base = constant(rates, num_steps)
    t = jnp.arange(num_steps)[:, None]
    in_spike = (t >= spike_start) & (t < spike_start + spike_len)
    col = jnp.arange(base.shape[1])[None, :] == spike_agent
    return jnp.where(in_spike & col, base * magnitude, base)


def scaled(rates: jnp.ndarray, num_steps: int, factor: float) -> jnp.ndarray:
    """Uniformly scaled demand, e.g. 3x overload (§V-B normalization test)."""
    return constant(jnp.asarray(rates, jnp.float32) * factor, num_steps)


def dominated(rates: jnp.ndarray, num_steps: int, agent: int, share: float = 0.9) -> jnp.ndarray:
    """One agent carries `share` of total requests (§V-B monopolization test)."""
    rates = jnp.asarray(rates, jnp.float32)
    total = rates.sum()
    n = rates.shape[0]
    others = jnp.full((n,), total * (1.0 - share) / (n - 1), jnp.float32)
    new_rates = others.at[agent].set(total * share)
    return constant(new_rates, num_steps)


def diurnal(rates: jnp.ndarray, num_steps: int, period: int = 50, depth: float = 0.5) -> jnp.ndarray:
    """Sinusoidal load swing — beyond-paper, exercises the predictive policy."""
    rates = jnp.asarray(rates, jnp.float32)
    t = jnp.arange(num_steps, dtype=jnp.float32)[:, None]
    mod = 1.0 + depth * jnp.sin(2.0 * jnp.pi * t / period)
    return rates[None, :] * mod
