"""Workload (arrival-process) generators for the fleet simulator.

The paper simulates 100 one-second steps with fixed per-agent arrival rates
(80/40/45/25 rps) and a fixed random seed.  Constant arrivals reproduce
Table II exactly; Poisson, spike, diurnal and domination processes support
the robustness study (§V-B) and beyond-paper experiments.  Two further
beyond-paper processes feed the sweep grid (``core/sweep.py``):

* ``bursty``     — two-state Markov-modulated (on/off) arrivals, independent
                   per agent: each agent flips between a burst regime
                   (``on_factor``·rate) and a lull (``off_factor``·rate) with
                   geometric dwell times, the classic MMPP burstiness model.
* ``correlated`` — fleet-wide surges: one shared on/off Markov chain scales
                   *all* agents simultaneously, modelling a collaborative-
                   reasoning cascade where one user request fans out to every
                   agent at once.

Every generator returns an (S, N) float32 array of arrivals per step and is
deterministic given its PRNG key, so sweeps are exactly reproducible.

**In-scan synthesis** (the streaming kernel's input side): every arrival
process also exists as a *per-step* generator in the **workload registry**
(``@register_workload``, mirroring the allocation-policy registry) with the
uniform signature

    (t, rates, knobs, state, key_t) -> (lam (N,), new_state (N,))

dispatched by ``lax.switch`` on a ``WorkloadSpec``'s traced ``gen_id`` —
exactly the ``CapacityConfig.policy_id`` pattern.  Randomness is
counter-based and stateless: ``key_t = jax.random.fold_in(spec.key, t)``,
so step t's draw needs no (S, N) slab and no sequential RNG state — the
streaming scan (``simulator.simulate_stream_core``) computes each step's
arrivals *inside* the ``lax.scan`` body from the O(N) parameter row.
Generators with genuine temporal state (the ``bursty``/``correlated`` MMPP
chains) carry it in the scan carry as an (N,) float vector (``state``);
stateless generators pass it through untouched.  ``materialize`` scans the
very same per-step functions into the classic (S, N) tensor, so the
materialized path is bit-for-bit the synthesized one by construction — it
is the parity oracle, never a second implementation.

``synthetic_rates`` generates the *base rate vector itself* for arbitrary
fleet sizes: random per-agent proportions of a fixed aggregate load
(default: the paper's 190 rps), so agent-count scaling sweeps
(``core/sweep.py::sweep_fleets``) hold total demand constant while N grows.
It draws from the same ``jax.random.key(seed)`` convention as every
stochastic generator here — one documented seed path for rate vectors and
arrival draws alike.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Σ of the paper's §IV-A arrival rates (80+40+45+25 rps).
PAPER_TOTAL_RATE = 190.0

# ``REPRO_SWEEP_SYNTH=0`` forces materialized arrivals everywhere, whatever
# the entry points were asked — the in-scan twin of ``REPRO_SWEEP_SHARD``.
SYNTH_ENV = "REPRO_SWEEP_SYNTH"


def synth_env_enabled() -> bool:
    """False iff ``REPRO_SWEEP_SYNTH=0`` (or ``false``/``off``) is set."""
    return os.environ.get(SYNTH_ENV, "").lower() not in ("0", "false", "off")


def synthetic_rates(
    num_agents: int,
    seed: int = 0,
    total_rate: float = PAPER_TOTAL_RATE,
    key: jax.Array | None = None,
) -> jnp.ndarray:
    """A reproducible per-agent rate vector summing to ``total_rate``.

    Proportions are drawn uniformly in [0.5, 1.5] and normalized, bounding
    any agent's share within 3x of any other's — heterogeneous but never
    degenerate, at any fleet size.

    The draw comes from ``jax.random.key(seed)`` (or an explicit ``key``) —
    the same counter-based convention as every stochastic generator in this
    module, so a sweep whose rate vectors and arrival draws descend from one
    key is exactly reproducible end to end.
    """
    if num_agents < 1:
        raise ValueError(f"num_agents must be >= 1, got {num_agents}")
    if key is None:
        key = jax.random.key(seed)
    w = jax.random.uniform(key, (num_agents,), minval=0.5, maxval=1.5)
    return jnp.asarray(total_rate * w / w.sum(), jnp.float32)


def constant(rates: jnp.ndarray, num_steps: int) -> jnp.ndarray:
    """lam_i(t) = rates_i for all t (reproduces the paper's Table II)."""
    rates = jnp.asarray(rates, jnp.float32)
    return jnp.broadcast_to(rates, (num_steps, rates.shape[0]))


def poisson(rates: jnp.ndarray, num_steps: int, key: jax.Array) -> jnp.ndarray:
    """Poisson(lam_i) arrivals per step, fixed seed for reproducibility."""
    rates = jnp.asarray(rates, jnp.float32)
    draws = jax.random.poisson(key, rates, shape=(num_steps, rates.shape[0]))
    return draws.astype(jnp.float32)


def spike(
    rates: jnp.ndarray,
    num_steps: int,
    spike_agent: int,
    spike_start: int,
    spike_len: int,
    magnitude: float = 10.0,
) -> jnp.ndarray:
    """10x arrival-rate spike on one agent (§V-B adaptation-speed test)."""
    base = constant(rates, num_steps)
    t = jnp.arange(num_steps)[:, None]
    in_spike = (t >= spike_start) & (t < spike_start + spike_len)
    col = jnp.arange(base.shape[1])[None, :] == spike_agent
    return jnp.where(in_spike & col, base * magnitude, base)


def scaled(rates: jnp.ndarray, num_steps: int, factor: float) -> jnp.ndarray:
    """Uniformly scaled demand, e.g. 3x overload (§V-B normalization test)."""
    return constant(jnp.asarray(rates, jnp.float32) * factor, num_steps)


def dominated_rates(rates: jnp.ndarray, agent: int, share: float = 0.9) -> jnp.ndarray:
    """Redistribute a rate vector so one agent carries ``share`` of the total
    (the §V-B monopolization rates; shared by ``dominated`` and
    ``dominated_spec``)."""
    rates = jnp.asarray(rates, jnp.float32)
    total = rates.sum()
    n = rates.shape[0]
    if n < 2:
        raise ValueError(
            "dominated needs >= 2 agents: with a single agent there is "
            f"nobody to redistribute the remaining {1.0 - share:.2f} share to"
        )
    others = jnp.full((n,), total * (1.0 - share) / (n - 1), jnp.float32)
    return others.at[agent].set(total * share)


def dominated(rates: jnp.ndarray, num_steps: int, agent: int, share: float = 0.9) -> jnp.ndarray:
    """One agent carries `share` of total requests (§V-B monopolization test)."""
    return constant(dominated_rates(rates, agent, share), num_steps)


def diurnal(rates: jnp.ndarray, num_steps: int, period: int = 50, depth: float = 0.5) -> jnp.ndarray:
    """Sinusoidal load swing — beyond-paper, exercises the predictive policy."""
    rates = jnp.asarray(rates, jnp.float32)
    t = jnp.arange(num_steps, dtype=jnp.float32)[:, None]
    mod = 1.0 + depth * jnp.sin(2.0 * jnp.pi * t / period)
    return rates[None, :] * mod


def bursty(
    rates: jnp.ndarray,
    num_steps: int,
    key: jax.Array,
    on_factor: float = 4.0,
    off_factor: float = 0.25,
    p_enter: float = 0.08,
    p_exit: float = 0.25,
) -> jnp.ndarray:
    """Markov-modulated on/off bursts, independent per agent.

    Each agent carries a two-state chain: a lull enters a burst with
    probability ``p_enter`` per step, a burst ends with ``p_exit``; the
    arrival rate is ``on_factor``·rate in a burst and ``off_factor``·rate in
    a lull.  Mean dwell times are geometric (1/p), giving heavy temporal
    correlation that constant/Poisson workloads lack.
    """
    rates = jnp.asarray(rates, jnp.float32)
    n = rates.shape[0]
    key_init, key_steps = jax.random.split(key)
    state0 = jax.random.bernoulli(key_init, 0.5, (n,))
    u = jax.random.uniform(key_steps, (num_steps, n))

    def step(state, ut):
        nxt = jnp.where(state, ut >= p_exit, ut < p_enter)
        factor = jnp.where(nxt, on_factor, off_factor)
        return nxt, factor

    _, factors = jax.lax.scan(step, state0, u)
    return rates[None, :] * factors


def correlated(
    rates: jnp.ndarray,
    num_steps: int,
    key: jax.Array,
    surge_factor: float = 4.0,
    p_enter: float = 0.05,
    p_exit: float = 0.2,
) -> jnp.ndarray:
    """Fleet-wide multi-agent surges: all agents spike *together*.

    A single shared on/off Markov chain multiplies every agent's rate by
    ``surge_factor`` during a surge — the arrival pattern of a collaborative
    reasoning burst, where one upstream request cascades to the whole fleet.
    """
    rates = jnp.asarray(rates, jnp.float32)
    u = jax.random.uniform(key, (num_steps,))

    def step(state, ut):
        nxt = jnp.where(state, ut >= p_exit, ut < p_enter)
        factor = jnp.where(nxt, surge_factor, 1.0)
        return nxt, factor

    _, factors = jax.lax.scan(step, jnp.asarray(False), u)
    return rates[None, :] * factors[:, None]


# -- workload registry: per-step generators for in-scan synthesis ------------

# Fixed-width generator parameter row: every spec carries KNOB_SLOTS floats
# whose meaning is per-generator (documented on each ``*_spec`` constructor);
# unused slots are zero.  A fixed width is what lets heterogeneous scenario
# columns stack into one (W, KNOB_SLOTS) leaf and dispatch via one switch.
KNOB_SLOTS = 4

# ``fold_in`` slot reserved for the generator's *initial* state draw; step t
# folds t, so any horizon below this never collides with it.
_INIT_FOLD = 0x7FFFFFFF


class _WorkloadGen(NamedTuple):
    step: Callable  # (t, rates, knobs, state, key_t) -> (lam (N,), state (N,))
    init: Callable  # (rates, knobs, key_init) -> state (N,)
    block: Callable  # (ts, rates, knobs, state, keys, unroll) -> (rows (B,N), state)


_WORKLOADS: dict[str, _WorkloadGen] = {}


def _zeros_init(rates, knobs, key):
    return jnp.zeros_like(rates)


def _scan_block(step: Callable) -> Callable:
    """Generic block synthesis: scan the step function over the block.

    The bit-identity *reference* — B sequential ``step`` calls with the
    per-t keys.  Every specialized block implementation below must match
    this exactly; it remains the default for generators registered without
    one.
    """

    def block(ts, rates, knobs, state, keys, unroll):
        def body(st, xs):
            t, key_t = xs
            lam, st = step(t, rates, knobs, st, key_t)
            return st, lam

        new_state, rows = jax.lax.scan(body, state, (ts, keys), unroll=unroll)
        return rows, new_state

    return block


def _batched_block(step: Callable) -> Callable:
    """Block synthesis for *stateless* generators: one vmapped call.

    A stateless step returns its state untouched, so the whole (B, N) block
    is a single batched evaluation over ``(ts, keys)`` — one RNG kernel per
    block instead of B sequential ones.  ``vmap`` of a deterministic
    function of ``(t, key_t)`` equals stacking the B scalar calls, so the
    rows are bit-identical to the scanned reference.
    """

    def block(ts, rates, knobs, state, keys, unroll):
        rows, _ = jax.vmap(lambda t, k: step(t, rates, knobs, state, k))(
            ts, keys
        )
        return rows, state

    return block


def register_workload(
    name: str,
    init: Callable | None = None,
    block: Callable | None = None,
    stateless: bool = False,
):
    """Register a per-step arrival generator under ``name``.

    ``fn(t, rates, knobs, state, key_t) -> (lam, state)`` computes step t's
    (N,) arrival row from the O(N) parameter row alone: ``key_t`` is already
    ``fold_in(spec.key, t)`` (counter-based — no sequential RNG state), and
    ``state`` is the (N,) float32 carry vector for generators with temporal
    state (MMPP chains); stateless generators return it untouched.  ``init``
    draws the t=0 state (default: zeros) from ``fold_in(spec.key,
    _INIT_FOLD)``.  Registration order defines ``workload_id`` — the
    ``lax.switch`` branch index, exactly like the policy registry.

    ``stateless=True`` marks a generator whose step ignores and passes
    through ``state``: its ``step_block`` branch becomes one vmapped batched
    call (``_batched_block``).  Stateful generators may register an explicit
    ``block`` that presamples their draws in batch and scans only the cheap
    state recurrence; omitting both falls back to the scanned reference
    (``_scan_block``).  Whatever the route, a block must be bit-identical to
    B sequential step calls — the parity property in
    tests/test_workload_synthesis.py enforces it per generator.
    """

    def deco(fn: Callable) -> Callable:
        if name in _WORKLOADS:
            raise ValueError(f"workload generator {name!r} already registered")
        if stateless:
            if block is not None:
                raise ValueError("stateless generators derive their block")
            blk = _batched_block(fn)
        else:
            blk = _scan_block(fn) if block is None else block
        _WORKLOADS[name] = _WorkloadGen(
            fn, _zeros_init if init is None else init, blk
        )
        return fn

    return deco


def workload_names() -> tuple[str, ...]:
    """Registered generator names, in registration (= switch-branch) order."""
    return tuple(_WORKLOADS)


def workload_id(name: str) -> int:
    """The ``lax.switch`` branch index of a registered generator."""
    if name not in _WORKLOADS:
        raise ValueError(
            f"unknown workload generator {name!r}; registered: {workload_names()}"
        )
    return list(_WORKLOADS).index(name)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WorkloadSpec:
    """An arrival process as an O(N) parameter row — the in-scan twin of a
    ``Scenario``'s (S, N) tensor.

    Array leaves (so specs stack/vmap/shard exactly like arrivals did):

    * ``gen_id``   — () int32 registry index, the ``lax.switch`` selector
      (the ``CapacityConfig.policy_id`` pattern);
    * ``rates``    — (N,) float32 base rates;
    * ``knobs``    — (KNOB_SLOTS,) float32 generator parameters;
    * ``key_data`` — (2,) uint32 raw PRNG key (``jax.random.key_data``; raw
      so it stacks under ``jnp.stack`` like any other leaf).

    ``name`` and ``num_steps`` are static aux data: the horizon is a trace
    constant (it sizes the scan), never a traced value.
    """

    gen_id: jnp.ndarray
    rates: jnp.ndarray
    knobs: jnp.ndarray
    key_data: jnp.ndarray
    name: str = "workload"
    num_steps: int = 100

    def tree_flatten(self):
        return (
            (self.gen_id, self.rates, self.knobs, self.key_data),
            (self.name, self.num_steps),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, name=aux[0], num_steps=aux[1])


def make_spec(
    gen: str,
    rates,
    num_steps: int,
    key: jax.Array | None = None,
    knobs: Sequence[float] = (),
    name: str | None = None,
) -> WorkloadSpec:
    """Build a ``WorkloadSpec`` for a registered generator.

    ``key`` defaults to ``jax.random.key(0)`` for deterministic generators
    (they never consume it).  ``num_steps`` must stay below the reserved
    init fold slot (2³¹−1) so step and init draws cannot collide.
    """
    if len(knobs) > KNOB_SLOTS:
        raise ValueError(f"at most {KNOB_SLOTS} knobs, got {len(knobs)}")
    if not 0 < int(num_steps) < _INIT_FOLD:
        raise ValueError(f"num_steps must be in (0, 2**31-1), got {num_steps}")
    kv = np.zeros(KNOB_SLOTS, np.float32)
    kv[: len(knobs)] = np.asarray(knobs, np.float32)
    if key is None:
        key = jax.random.key(0)
    return WorkloadSpec(
        gen_id=jnp.asarray(workload_id(gen), jnp.int32),
        rates=jnp.asarray(rates, jnp.float32),
        knobs=jnp.asarray(kv),
        key_data=jax.random.key_data(key),
        name=gen if name is None else name,
        num_steps=int(num_steps),
    )


def workload_init(spec: WorkloadSpec, gen: str | None = None) -> jnp.ndarray:
    """The generator's t=0 carry state, drawn from the reserved init fold.

    ``gen`` names the generator *statically* when the caller knows it at
    trace time (the grouped-dispatch sweep path): the ``lax.switch`` is
    replaced by a direct call, so a vmapped caller does not lower every
    registered branch.  The dispatched function is identical either way —
    the draw is bit-for-bit the same.
    """
    key_init = jax.random.fold_in(
        jax.random.wrap_key_data(spec.key_data), _INIT_FOLD
    )
    if gen is not None:
        return _WORKLOADS[gen].init(spec.rates, spec.knobs, key_init)
    return jax.lax.switch(
        spec.gen_id,
        [g.init for g in _WORKLOADS.values()],
        spec.rates, spec.knobs, key_init,
    )


def workload_step(
    spec: WorkloadSpec,
    state: jnp.ndarray,
    t: jnp.ndarray,
    gen: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Step t's (N,) arrival row + next carry state, by switch dispatch.

    Pure in t: the key is ``fold_in(spec.key, t)``, so the same (spec,
    state, t) triple always yields the same draw — inside a scan, under
    vmap, or called eagerly (the oracle's python loop).  A static ``gen``
    bypasses the switch (see ``workload_init``): under ``vmap`` the switch
    lowers to evaluate-all-branches-and-select, which makes every scenario
    column pay every registered generator — the expensive ones (poisson's
    iterative sampler) dominate whole sweeps.  Same function, same key,
    same bits; only the dispatch differs.
    """
    key_t = jax.random.fold_in(jax.random.wrap_key_data(spec.key_data), t)
    if gen is not None:
        return _WORKLOADS[gen].step(t, spec.rates, spec.knobs, state, key_t)
    return jax.lax.switch(
        spec.gen_id,
        [g.step for g in _WORKLOADS.values()],
        t, spec.rates, spec.knobs, state, key_t,
    )


# Unroll cap for the generators' small recurrence scans (the MMPP state
# threading in the block implementations above): XLA CPU compile time grows
# superlinearly in unrolled-body size, so blocks longer than this run as a
# rolled loop over MAX_UNROLL-step unrolled chunks.  Only these tiny bodies
# unroll at all — unrolling the streaming kernel's full physics step was
# measured a net loss on XLA CPU (~1.7× slower execution and ~6× longer
# compiles at B=128 than the rolled loop), so the simulator keeps its inner
# scan rolled.
MAX_UNROLL = 16


def step_block(
    spec: WorkloadSpec,
    state: jnp.ndarray,
    ts: jnp.ndarray,
    unroll: int | None = None,
    gen: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Synthesize a whole (B, N) arrival block in one call.

    ``ts`` is the (B,) int32 step-counter vector of the block.  The per-step
    keys are the same counter-based ``fold_in(spec.key, t)`` draws the
    scalar path makes — batched through ``vmap`` (pure integer hashing, so
    bit-exact under batching) — and each generator's registered *block*
    function synthesizes its rows from them: stateless generators as one
    vmapped batched call (one RNG kernel per block instead of B), stateful
    MMPP generators by presampling their uniforms in batch and scanning
    only the cheap state recurrence, unrolled ``unroll`` steps at a time
    (default ``min(B, MAX_UNROLL)``).  One ``lax.switch`` dispatch per
    block replaces B per-step dispatches; every route is bit-identical to
    B sequential ``workload_step`` calls (same draws per ``(spec, t)``,
    same recurrence ops, same state threading — the parity property in
    tests/test_workload_synthesis.py checks each generator).

    A static ``gen`` skips the switch entirely (see ``workload_step`` — the
    vmapped switch's evaluate-all-branches lowering is what makes every
    scenario pay the poisson sampler); the grouped sweep path passes it.
    """
    b = ts.shape[0]
    u = min(b, MAX_UNROLL) if unroll is None else int(unroll)
    keys = jax.vmap(
        lambda t: jax.random.fold_in(jax.random.wrap_key_data(spec.key_data), t)
    )(ts)
    if gen is not None:
        return _WORKLOADS[gen].block(ts, spec.rates, spec.knobs, state, keys, u)

    def branch(g: _WorkloadGen):
        return lambda: g.block(ts, spec.rates, spec.knobs, state, keys, u)

    rows, new_state = jax.lax.switch(
        spec.gen_id, [branch(g) for g in _WORKLOADS.values()]
    )
    return rows, new_state


@functools.partial(jax.jit, static_argnames=("num_steps",))
def _materialize_jit(spec: WorkloadSpec, num_steps: int) -> jnp.ndarray:
    def step(state, t):
        lam, state = workload_step(spec, state, t)
        return state, lam

    _, rows = jax.lax.scan(
        step, workload_init(spec), jnp.arange(num_steps, dtype=jnp.int32)
    )
    return rows


def materialize(spec: WorkloadSpec, num_steps: int | None = None) -> jnp.ndarray:
    """Scan the per-step generator into the classic (S, N) arrival tensor.

    This IS the materialized parity path: it runs the very same registered
    step functions the streaming scan runs in its body, so synthesized and
    materialized arrivals are bit-for-bit identical by construction — there
    is no second generator implementation to drift.
    """
    steps = spec.num_steps if num_steps is None else int(num_steps)
    return _materialize_jit(spec, steps)


def stack_specs(specs: Sequence[WorkloadSpec], name: str = "stacked") -> WorkloadSpec:
    """Stack specs along a new leading axis (the scenario column of a sweep).

    All horizons must agree (the scan length is one static trace constant);
    leaves gain the axis exactly as ``jnp.stack`` over arrivals tensors did,
    so stacked specs shard/vmap under the same partition specs as arrivals.
    Already-stacked specs stack again — the (F, W, ...) fleet-sweep block.
    """
    specs = list(specs)
    if not specs:
        raise ValueError("stack_specs needs at least one spec")
    steps = {s.num_steps for s in specs}
    if len(steps) != 1:
        raise ValueError(f"specs must share one horizon, got {sorted(steps)}")
    return WorkloadSpec(
        gen_id=jnp.stack([s.gen_id for s in specs]),
        rates=jnp.stack([s.rates for s in specs]),
        knobs=jnp.stack([s.knobs for s in specs]),
        key_data=jnp.stack([s.key_data for s in specs]),
        name=name,
        num_steps=steps.pop(),
    )


# -- registered generators ---------------------------------------------------
#
# Per-generator ``knobs`` layout (unused slots zero):
#   constant    —
#   poisson     —
#   spike       (agent, start, length, magnitude)
#   diurnal     (period, depth)
#   bursty      (on_factor, off_factor, p_enter, p_exit)
#   correlated  (surge_factor, p_enter, p_exit)
#
# ``scaled``/``dominated``/``overload`` scenarios are ``constant`` specs over
# transformed rate vectors — a rate transform, not a distinct process.


@register_workload("constant", stateless=True)
def _constant_step(t, rates, knobs, state, key_t):
    return rates, state


@register_workload("poisson", stateless=True)
def _poisson_step(t, rates, knobs, state, key_t):
    draws = jax.random.poisson(key_t, rates, shape=rates.shape)
    return draws.astype(jnp.float32), state


@register_workload("spike", stateless=True)
def _spike_step(t, rates, knobs, state, key_t):
    agent, start, length, magnitude = knobs[0], knobs[1], knobs[2], knobs[3]
    tf = t.astype(jnp.float32)  # exact for any horizon below 2**24
    in_spike = (tf >= start) & (tf < start + length)
    col = jnp.arange(rates.shape[0], dtype=jnp.float32) == agent
    return jnp.where(in_spike & col, rates * magnitude, rates), state


@register_workload("diurnal", stateless=True)
def _diurnal_step(t, rates, knobs, state, key_t):
    period, depth = knobs[0], knobs[1]
    mod = 1.0 + depth * jnp.sin(2.0 * jnp.pi * t.astype(jnp.float32) / period)
    return rates * mod, state


def _bursty_init(rates, knobs, key):
    return jax.random.bernoulli(key, 0.5, rates.shape).astype(jnp.float32)


def _bursty_advance(rates, knobs, state, u):
    # The one MMPP recurrence implementation — step and block both go
    # through it, so the two paths cannot drift.
    on, off, p_enter, p_exit = knobs[0], knobs[1], knobs[2], knobs[3]
    nxt = jnp.where(state > 0.5, u >= p_exit, u < p_enter)
    lam = rates * jnp.where(nxt, on, off)
    return lam, nxt.astype(jnp.float32)


def _bursty_block(ts, rates, knobs, state, keys, unroll):
    # Presample the whole block's uniforms in one batched draw; only the
    # cheap where-threading recurrence stays sequential.
    u = jax.vmap(lambda k: jax.random.uniform(k, rates.shape))(keys)

    def body(st, u_t):
        lam, st = _bursty_advance(rates, knobs, st, u_t)
        return st, lam

    new_state, rows = jax.lax.scan(body, state, u, unroll=unroll)
    return rows, new_state


@register_workload("bursty", init=_bursty_init, block=_bursty_block)
def _bursty_step(t, rates, knobs, state, key_t):
    return _bursty_advance(
        rates, knobs, state, jax.random.uniform(key_t, rates.shape)
    )


def _correlated_advance(rates, knobs, state, u):
    surge, p_enter, p_exit = knobs[0], knobs[1], knobs[2]
    nxt = jnp.where(state[0] > 0.5, u >= p_exit, u < p_enter)
    lam = rates * jnp.where(nxt, surge, 1.0)
    # The shared chain's single bit, broadcast so every generator's state
    # leaf has one (N,) shape under the switch.
    return lam, jnp.broadcast_to(nxt.astype(jnp.float32), rates.shape)


def _correlated_block(ts, rates, knobs, state, keys, unroll):
    u = jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)

    def body(st, u_t):
        lam, st = _correlated_advance(rates, knobs, st, u_t)
        return st, lam

    new_state, rows = jax.lax.scan(body, state, u, unroll=unroll)
    return rows, new_state


@register_workload("correlated", block=_correlated_block)
def _correlated_step(t, rates, knobs, state, key_t):
    return _correlated_advance(
        rates, knobs, state, jax.random.uniform(key_t, ())
    )


# -- spec constructors (one per scenario type) -------------------------------


def constant_spec(rates, num_steps: int, name: str = "constant") -> WorkloadSpec:
    return make_spec("constant", rates, num_steps, name=name)


def poisson_spec(rates, num_steps: int, key: jax.Array) -> WorkloadSpec:
    return make_spec("poisson", rates, num_steps, key=key)


def spike_spec(
    rates,
    num_steps: int,
    spike_agent: int,
    spike_start: int,
    spike_len: int,
    magnitude: float = 10.0,
) -> WorkloadSpec:
    return make_spec(
        "spike", rates, num_steps,
        knobs=(float(spike_agent), float(spike_start), float(spike_len), magnitude),
    )


def scaled_spec(rates, num_steps: int, factor: float, name: str = "scaled") -> WorkloadSpec:
    rates = jnp.asarray(rates, jnp.float32) * factor
    return make_spec("constant", rates, num_steps, name=name)


def dominated_spec(
    rates, num_steps: int, agent: int, share: float = 0.9
) -> WorkloadSpec:
    return make_spec(
        "constant", dominated_rates(rates, agent, share), num_steps,
        name="dominated",
    )


def diurnal_spec(
    rates, num_steps: int, period: int = 50, depth: float = 0.5
) -> WorkloadSpec:
    return make_spec("diurnal", rates, num_steps, knobs=(float(period), depth))


def bursty_spec(
    rates,
    num_steps: int,
    key: jax.Array,
    on_factor: float = 4.0,
    off_factor: float = 0.25,
    p_enter: float = 0.08,
    p_exit: float = 0.25,
) -> WorkloadSpec:
    return make_spec(
        "bursty", rates, num_steps, key=key,
        knobs=(on_factor, off_factor, p_enter, p_exit),
    )


def correlated_spec(
    rates,
    num_steps: int,
    key: jax.Array,
    surge_factor: float = 4.0,
    p_enter: float = 0.05,
    p_exit: float = 0.2,
) -> WorkloadSpec:
    return make_spec(
        "correlated", rates, num_steps, key=key,
        knobs=(surge_factor, p_enter, p_exit),
    )


def scenario_specs(
    rates, num_steps: int = 100, seed: int = 0
) -> tuple[WorkloadSpec, ...]:
    """The standard 8-scenario library as O(N) specs — the in-scan twin of
    ``sweep.scenario_library`` (same names, same scenario semantics; the
    stochastic per-step draws come from fold_in counters rather than one
    pre-split (S, N) block, so values differ from the legacy tensors but are
    equally reproducible from ``seed``)."""
    rates = jnp.asarray(rates, jnp.float32)
    n = int(rates.shape[0])
    k_poisson, k_bursty, k_corr = jax.random.split(jax.random.key(seed), 3)
    return (
        constant_spec(rates, num_steps),
        poisson_spec(rates, num_steps, k_poisson),
        spike_spec(
            rates, num_steps,
            spike_agent=n - 1,
            spike_start=num_steps // 2,
            spike_len=max(num_steps // 10, 1),
        ),
        scaled_spec(rates, num_steps, 3.0, name="overload_3x"),
        dominated_spec(rates, num_steps, agent=0, share=0.9),
        diurnal_spec(rates, num_steps),
        bursty_spec(rates, num_steps, k_bursty),
        correlated_spec(rates, num_steps, k_corr),
    )


def fleet_scenario_specs(
    rate_vectors: Sequence,
    n_max: int,
    num_steps: int = 100,
    seed: int = 0,
) -> tuple[tuple[str, ...], tuple[tuple[WorkloadSpec, ...], ...]]:
    """Per-fleet spec columns at a common padded width — the spec twin of
    ``sweep.fleet_scenario_library``.

    Rate transforms (spike target, dominated redistribution) are computed at
    each fleet's *true* width, then the rate vector is zero-padded to
    ``n_max``: every registered generator yields exactly zero arrivals for a
    zero-rate agent, so padded slots stay inert without any masking beyond
    what the simulator already applies.  Returns ``(scenario_names,
    specs[fleet][scenario])``; stack with ``stack_specs`` for the (F, W)
    grid or ``materialize`` each for the parity arm.
    """
    names: tuple[str, ...] | None = None
    rows = []
    for rates in rate_vectors:
        r = np.asarray(rates, np.float32)
        true_n = int(r.shape[-1])
        if true_n > n_max:
            raise ValueError(f"rate vector wider ({true_n}) than n_max={n_max}")
        padded = np.pad(r, (0, n_max - true_n))
        k_poisson, k_bursty, k_corr = jax.random.split(jax.random.key(seed), 3)
        dom = np.zeros(n_max, np.float32)
        dom[:true_n] = np.asarray(dominated_rates(r, agent=0, share=0.9))
        lib = (
            constant_spec(padded, num_steps),
            poisson_spec(padded, num_steps, k_poisson),
            spike_spec(
                padded, num_steps,
                spike_agent=true_n - 1,
                spike_start=num_steps // 2,
                spike_len=max(num_steps // 10, 1),
            ),
            scaled_spec(padded, num_steps, 3.0, name="overload_3x"),
            make_spec("constant", dom, num_steps, name="dominated"),
            diurnal_spec(padded, num_steps),
            bursty_spec(padded, num_steps, k_bursty),
            correlated_spec(padded, num_steps, k_corr),
        )
        lib_names = tuple(s.name for s in lib)
        if names is None:
            names = lib_names
        elif names != lib_names:
            raise ValueError("scenario spec libraries diverged across fleets")
        rows.append(lib)
    return names, tuple(rows)
