"""Workload (arrival-process) generators for the fleet simulator.

The paper simulates 100 one-second steps with fixed per-agent arrival rates
(80/40/45/25 rps) and a fixed random seed.  Constant arrivals reproduce
Table II exactly; Poisson, spike, diurnal and domination processes support
the robustness study (§V-B) and beyond-paper experiments.  Two further
beyond-paper processes feed the sweep grid (``core/sweep.py``):

* ``bursty``     — two-state Markov-modulated (on/off) arrivals, independent
                   per agent: each agent flips between a burst regime
                   (``on_factor``·rate) and a lull (``off_factor``·rate) with
                   geometric dwell times, the classic MMPP burstiness model.
* ``correlated`` — fleet-wide surges: one shared on/off Markov chain scales
                   *all* agents simultaneously, modelling a collaborative-
                   reasoning cascade where one user request fans out to every
                   agent at once.

Every generator returns an (S, N) float32 array of arrivals per step and is
deterministic given its PRNG key, so sweeps are exactly reproducible.

``synthetic_rates`` generates the *base rate vector itself* for arbitrary
fleet sizes: random per-agent proportions of a fixed aggregate load
(default: the paper's 190 rps), so agent-count scaling sweeps
(``core/sweep.py::sweep_fleets``) hold total demand constant while N grows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Σ of the paper's §IV-A arrival rates (80+40+45+25 rps).
PAPER_TOTAL_RATE = 190.0


def synthetic_rates(
    num_agents: int, seed: int = 0, total_rate: float = PAPER_TOTAL_RATE
) -> jnp.ndarray:
    """A reproducible per-agent rate vector summing to ``total_rate``.

    Proportions are drawn uniformly in [0.5, 1.5] and normalized, bounding
    any agent's share within 3x of any other's — heterogeneous but never
    degenerate, at any fleet size.
    """
    if num_agents < 1:
        raise ValueError(f"num_agents must be >= 1, got {num_agents}")
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 1.5, num_agents)
    return jnp.asarray(total_rate * w / w.sum(), jnp.float32)


def constant(rates: jnp.ndarray, num_steps: int) -> jnp.ndarray:
    """lam_i(t) = rates_i for all t (reproduces the paper's Table II)."""
    rates = jnp.asarray(rates, jnp.float32)
    return jnp.broadcast_to(rates, (num_steps, rates.shape[0]))


def poisson(rates: jnp.ndarray, num_steps: int, key: jax.Array) -> jnp.ndarray:
    """Poisson(lam_i) arrivals per step, fixed seed for reproducibility."""
    rates = jnp.asarray(rates, jnp.float32)
    draws = jax.random.poisson(key, rates, shape=(num_steps, rates.shape[0]))
    return draws.astype(jnp.float32)


def spike(
    rates: jnp.ndarray,
    num_steps: int,
    spike_agent: int,
    spike_start: int,
    spike_len: int,
    magnitude: float = 10.0,
) -> jnp.ndarray:
    """10x arrival-rate spike on one agent (§V-B adaptation-speed test)."""
    base = constant(rates, num_steps)
    t = jnp.arange(num_steps)[:, None]
    in_spike = (t >= spike_start) & (t < spike_start + spike_len)
    col = jnp.arange(base.shape[1])[None, :] == spike_agent
    return jnp.where(in_spike & col, base * magnitude, base)


def scaled(rates: jnp.ndarray, num_steps: int, factor: float) -> jnp.ndarray:
    """Uniformly scaled demand, e.g. 3x overload (§V-B normalization test)."""
    return constant(jnp.asarray(rates, jnp.float32) * factor, num_steps)


def dominated(rates: jnp.ndarray, num_steps: int, agent: int, share: float = 0.9) -> jnp.ndarray:
    """One agent carries `share` of total requests (§V-B monopolization test)."""
    rates = jnp.asarray(rates, jnp.float32)
    total = rates.sum()
    n = rates.shape[0]
    if n < 2:
        raise ValueError(
            "dominated needs >= 2 agents: with a single agent there is "
            f"nobody to redistribute the remaining {1.0 - share:.2f} share to"
        )
    others = jnp.full((n,), total * (1.0 - share) / (n - 1), jnp.float32)
    new_rates = others.at[agent].set(total * share)
    return constant(new_rates, num_steps)


def diurnal(rates: jnp.ndarray, num_steps: int, period: int = 50, depth: float = 0.5) -> jnp.ndarray:
    """Sinusoidal load swing — beyond-paper, exercises the predictive policy."""
    rates = jnp.asarray(rates, jnp.float32)
    t = jnp.arange(num_steps, dtype=jnp.float32)[:, None]
    mod = 1.0 + depth * jnp.sin(2.0 * jnp.pi * t / period)
    return rates[None, :] * mod


def bursty(
    rates: jnp.ndarray,
    num_steps: int,
    key: jax.Array,
    on_factor: float = 4.0,
    off_factor: float = 0.25,
    p_enter: float = 0.08,
    p_exit: float = 0.25,
) -> jnp.ndarray:
    """Markov-modulated on/off bursts, independent per agent.

    Each agent carries a two-state chain: a lull enters a burst with
    probability ``p_enter`` per step, a burst ends with ``p_exit``; the
    arrival rate is ``on_factor``·rate in a burst and ``off_factor``·rate in
    a lull.  Mean dwell times are geometric (1/p), giving heavy temporal
    correlation that constant/Poisson workloads lack.
    """
    rates = jnp.asarray(rates, jnp.float32)
    n = rates.shape[0]
    key_init, key_steps = jax.random.split(key)
    state0 = jax.random.bernoulli(key_init, 0.5, (n,))
    u = jax.random.uniform(key_steps, (num_steps, n))

    def step(state, ut):
        nxt = jnp.where(state, ut >= p_exit, ut < p_enter)
        factor = jnp.where(nxt, on_factor, off_factor)
        return nxt, factor

    _, factors = jax.lax.scan(step, state0, u)
    return rates[None, :] * factors


def correlated(
    rates: jnp.ndarray,
    num_steps: int,
    key: jax.Array,
    surge_factor: float = 4.0,
    p_enter: float = 0.05,
    p_exit: float = 0.2,
) -> jnp.ndarray:
    """Fleet-wide multi-agent surges: all agents spike *together*.

    A single shared on/off Markov chain multiplies every agent's rate by
    ``surge_factor`` during a surge — the arrival pattern of a collaborative
    reasoning burst, where one upstream request cascades to the whole fleet.
    """
    rates = jnp.asarray(rates, jnp.float32)
    u = jax.random.uniform(key, (num_steps,))

    def step(state, ut):
        nxt = jnp.where(state, ut >= p_exit, ut < p_enter)
        factor = jnp.where(nxt, surge_factor, 1.0)
        return nxt, factor

    _, factors = jax.lax.scan(step, jnp.asarray(False), u)
    return rates[None, :] * factors[:, None]
