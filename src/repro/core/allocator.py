"""GPU-fraction allocation policies.

``adaptive_allocation`` is the paper's contribution (Algorithm 1), kept
faithful line-for-line.  ``static_equal`` and ``round_robin`` are the paper's
baselines.  The remaining policies are *beyond-paper* extensions recorded
separately in EXPERIMENTS.md §Perf:

* ``water_filling``        — equalizes Little's-law latency q/(g·T) across
                             agents (minimizes the max-latency agent).
* ``predictive_adaptive``  — Algorithm 1 driven by an EMA forecast of the
                             arrival rate instead of the instantaneous rate.
* ``throughput_greedy``    — maximizes Σ served subject to minimum
                             guarantees (upper bound on raw throughput).

All policies are pure jnp, O(N), and jittable; each returns g with
Σ g <= g_total and g >= 0.

Every policy is also registered in the **policy registry** (bottom of this
module) under a uniform signature

    (t, lam_obs, lam_ema, queue, fleet, g_total) -> g

``g_total`` may be a static python float (the provisioned budget) **or a
traced scalar**: under the serverless capacity layer (``core/capacity.py``)
the budget is the warm-pool trajectory ``g_total(t) = warm(t)``, including
exact zeros when the pool scales to zero — every registry entry must (and
does) emit Σ g <= g_total(t) and g >= 0 for any time-varying traced budget
(property-tested in tests/test_policy_invariants.py).

Under workflow routing (``core/routing.py``) ``lam_obs`` is the agent's
*total* intake — exogenous arrivals plus requests routed from upstream
agents — and ``queue`` carries any backlog of routed traffic, so
queue-pressure policies (``water_filling``, ``throughput_greedy``,
``objective_descent``) and rate-driven ones (``adaptive``, ``predictive``)
all see endogenous demand without any per-policy changes.

The registry is the single source of truth for dispatch: the simulator's
``lax.switch`` branches, the serving engine's per-tick dispatch, and the
vmapped sweep grid (``core/sweep.py``) are all built from it, so adding a
policy here makes it available everywhere with no other edits.

Registry entries are **mask-aware**: ``fleet.active`` (the agent-validity
mask, see ``core/agents.py``) gates every input, so padded slots contribute
zero demand and receive exactly g = 0, and ``static_equal``/``round_robin``
divide by the *traced* active-agent count rather than a Python int — the
whole registry therefore vmaps over a batched fleet axis of heterogeneous
(padded) fleet sizes.
"""
from __future__ import annotations

from typing import Callable, Sequence, TYPE_CHECKING

import jax
import jax.numpy as jnp

if TYPE_CHECKING:
    from repro.core.agents import Fleet

_EPS = 1e-9


def _normalize_capacity(g: jnp.ndarray, g_total: float) -> jnp.ndarray:
    """Algorithm 1 lines 19-25: proportional scale-down iff over capacity."""
    allocated = g.sum()
    scale = jnp.where(allocated > g_total, g_total / jnp.maximum(allocated, _EPS), 1.0)
    return g * scale


def adaptive_allocation(
    lam: jnp.ndarray,
    min_gpu: jnp.ndarray,
    priority: jnp.ndarray,
    g_total: float = 1.0,
) -> jnp.ndarray:
    """Paper Algorithm 1, faithful.

    demand        d_i = lam_i * R_i / P_i                 (line 5)
    proportional  g_i = d_i / D_total * G_total           (line 15)
    minimum       g_i = max(R_i, g_i)                     (line 16)
    normalize     g *= G_total / G_allocated if over      (lines 21-25)
    All-idle fleets (D_total == 0) release everything     (lines 10-12).
    """
    demand = lam * min_gpu / priority
    d_total = demand.sum()
    prop = demand / jnp.maximum(d_total, _EPS) * g_total
    g = jnp.maximum(min_gpu, prop)
    g = _normalize_capacity(g, g_total)
    return jnp.where(d_total > 0, g, jnp.zeros_like(g))


def static_equal(num_agents: int, g_total: float = 1.0) -> jnp.ndarray:
    """Baseline: G_total/N to every agent, regardless of load."""
    return jnp.full((num_agents,), g_total / num_agents, jnp.float32)


def masked_static_equal(active: jnp.ndarray, g_total: float = 1.0) -> jnp.ndarray:
    """``static_equal`` over the *traced* active-agent count: G_total/N_active
    to each unmasked agent, 0 to padding.  Identical to ``static_equal`` when
    the mask is all-ones; vmappable over a batched fleet axis."""
    n_active = jnp.maximum(active.sum(), 1.0)
    return (active * (g_total / n_active)).astype(jnp.float32)


def round_robin(t: jnp.ndarray, num_agents: int, g_total: float = 1.0) -> jnp.ndarray:
    """Baseline: 100% of the GPU to agent (t mod N) — '100% sequential'."""
    return jax.nn.one_hot(jnp.mod(t, num_agents), num_agents, dtype=jnp.float32) * g_total


def masked_round_robin(
    t: jnp.ndarray, active: jnp.ndarray, g_total: float = 1.0
) -> jnp.ndarray:
    """``round_robin`` over active agents only: the full GPU goes to the
    (t mod N_active)-th *unmasked* agent.  With an all-ones mask the active
    ranks are 0..N-1 and this reduces exactly to ``round_robin``.

    The rotation is integer arithmetic: a float32 mod would lose tick
    precision past 2^24 and skip agents in a long-running engine.
    """
    n_active = jnp.maximum(active.sum().astype(jnp.int32), 1)
    rank = (jnp.cumsum(active) - 1.0).astype(jnp.int32)  # rank among active
    chosen = jnp.mod(jnp.asarray(t).astype(jnp.int32), n_active)
    return (active * jnp.where(rank == chosen, g_total, 0.0)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Beyond-paper policies.
# ---------------------------------------------------------------------------

def water_filling(
    queue: jnp.ndarray,
    lam: jnp.ndarray,
    base_throughput: jnp.ndarray,
    min_gpu: jnp.ndarray,
    g_total: float = 1.0,
) -> jnp.ndarray:
    """Equalize projected latency (q + lam)/(g·T) across busy agents.

    Solving for equal latency gives g_i ∝ (q_i + lam_i)/T_i; minimum
    guarantees and capacity normalization are applied as in Algorithm 1 so
    the policy is a drop-in replacement.
    """
    pressure = (queue + lam) / jnp.maximum(base_throughput, _EPS)
    total = pressure.sum()
    prop = pressure / jnp.maximum(total, _EPS) * g_total
    g = jnp.maximum(jnp.where(pressure > 0, min_gpu, 0.0), prop)
    g = _normalize_capacity(g, g_total)
    return jnp.where(total > 0, g, jnp.zeros_like(g))


def sqrt_demand(
    queue: jnp.ndarray,
    lam: jnp.ndarray,
    base_throughput: jnp.ndarray,
    min_gpu: jnp.ndarray,
    g_total: float = 1.0,
) -> jnp.ndarray:
    """Square-root fair share: g_i ∝ √((q_i + lam_i)/T_i).

    The sublinear weight is the classic square-root rule (cf. √N staffing):
    heavy agents still get more GPU, but the concave weighting shields
    light agents from starvation during skewed bursts — a cheap middle
    ground between ``static_equal`` and ``water_filling``.  Floors and
    capacity normalization follow Algorithm 1, keyed on the *raw* pressure
    (same busy set as water-filling).
    """
    pressure = (queue + lam) / jnp.maximum(base_throughput, _EPS)
    weight = jnp.sqrt(pressure)
    total = weight.sum()
    prop = weight / jnp.maximum(total, _EPS) * g_total
    g = jnp.maximum(jnp.where(pressure > 0, min_gpu, 0.0), prop)
    g = _normalize_capacity(g, g_total)
    return jnp.where(total > 0, g, jnp.zeros_like(g))


def ema_water_filling(
    queue: jnp.ndarray,
    lam_ema: jnp.ndarray,
    base_throughput: jnp.ndarray,
    min_gpu: jnp.ndarray,
    g_total: float = 1.0,
) -> jnp.ndarray:
    """Latency-EMA-weighted water-filling: equalize the *forecast* drain
    time (q_i + ema_i)/(g_i·T_i) instead of the instantaneous one.

    Same fixed point as ``water_filling`` under steady load, but the EMA
    smoothing keeps allocations from thrashing on bursty arrivals — the
    predictive counterpart of water-filling, exactly as ``predictive`` is
    the EMA counterpart of ``adaptive``.
    """
    return water_filling(queue, lam_ema, base_throughput, min_gpu, g_total)


def _committed(x: jnp.ndarray) -> jnp.ndarray:
    """Pin ``x`` to its rounded float32 value against FMA contraction.

    XLA CPU freely contracts ``a·b + c`` into a fused multiply-add (one
    rounding) or not (two roundings) depending on how the surrounding
    program vectorizes — so the *same* expression can differ by 1 ulp
    between two compilations (e.g. the in-scan-synthesis and materialized
    arms of the streaming kernel, which promise bit-identical metrics).
    ``lax.optimization_barrier`` does not help: it is erased before LLVM
    codegen, where the contraction happens.  A select on a data-dependent
    predicate does — ``x == x`` is only false for NaN, which no simplifier
    can prove away, and a select between the multiply and the add breaks
    the contraction pattern while preserving values exactly (NaN stays
    NaN via the on-false branch).
    """
    return jnp.where(x == x, x, jnp.full_like(x, jnp.nan))


def ema_forecast(lam_prev_ema: jnp.ndarray, lam_obs: jnp.ndarray, alpha: float = 0.3) -> jnp.ndarray:
    """One EMA update; the predictive policy's workload model.

    Both products are committed to rounded f32 before the add so the
    update has fixed two-rounding semantics in every compilation — the
    EMA is the one recurrence whose 1-ulp contraction drift was observed
    to break the synthesized-vs-materialized bit-identity contract.
    """
    return _committed(alpha * lam_obs) + _committed((1.0 - alpha) * lam_prev_ema)


def predictive_adaptive(
    lam_ema: jnp.ndarray,
    min_gpu: jnp.ndarray,
    priority: jnp.ndarray,
    g_total: float = 1.0,
) -> jnp.ndarray:
    """Algorithm 1 on the EMA-forecast arrival rate (paper §VI future work)."""
    return adaptive_allocation(lam_ema, min_gpu, priority, g_total)


def throughput_greedy(
    queue: jnp.ndarray,
    lam: jnp.ndarray,
    base_throughput: jnp.ndarray,
    min_gpu: jnp.ndarray,
    g_total: float = 1.0,
) -> jnp.ndarray:
    """Maximize Σ_i min(g_i·T_i, q_i + lam_i) s.t. g >= R on busy agents.

    Greedy water-fill by throughput density: after satisfying minimums,
    residual capacity goes to agents in decreasing T_i order until each
    agent's backlog is covered (g_i·T_i == q_i + lam_i).  O(N log N) for the
    sort; still trivially real-time.
    """
    busy = (queue + lam) > 0
    g = jnp.where(busy, min_gpu, 0.0)
    # Fraction needed to clear the whole backlog this step.
    need = jnp.where(busy, (queue + lam) / jnp.maximum(base_throughput, _EPS), 0.0)
    extra_need = jnp.maximum(need - g, 0.0)
    residual = jnp.maximum(g_total - g.sum(), 0.0)
    # Allocate residual to the highest-throughput agents first.
    order = jnp.argsort(-base_throughput)
    sorted_need = extra_need[order]
    cum_before = jnp.cumsum(sorted_need) - sorted_need
    grant_sorted = jnp.clip(residual - cum_before, 0.0, sorted_need)
    grant = jnp.zeros_like(grant_sorted).at[order].set(grant_sorted)
    g = g + grant
    return _normalize_capacity(g, g_total)


def objective_descent(
    queue: jnp.ndarray,
    lam: jnp.ndarray,
    base_throughput: jnp.ndarray,
    min_gpu: jnp.ndarray,
    priority: jnp.ndarray,
    g_total: float = 1.0,
    *,
    alpha: float = 1.0,
    gamma: float = 10.0,
    steps: int = 12,
    lr: float = 0.05,
    latency_cap: float = 1000.0,
    active: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Directly optimize the paper's Eq. (2) by projected gradient.

    One-step lookahead objective  alpha·L(g) − gamma·H(g)  (cost term is
    constant in g for a provisioned device), differentiated through the
    smooth queue dynamics; projection = clip to [R_i·busy, 1] then
    capacity-normalize.  Still O(N) per iteration, `steps` iterations —
    ~12x Algorithm 1's cost, far under the paper's 1 ms budget.

    ``active`` masks out padded agents: their latency leaves the objective
    mean and projection pins them at g = 0, so a padded fleet descends the
    same trajectory as its unpadded original.
    """
    mask = jnp.ones_like(queue) if active is None else active
    busy = mask * (queue + lam) > 0
    floor = jnp.where(busy, min_gpu, 0.0)
    n_active = jnp.maximum(mask.sum(), 1.0)

    def objective(g):
        capacity = g * base_throughput
        served = jnp.minimum(capacity, queue + lam) * mask
        new_q = (queue + lam) * mask - served
        lat = jnp.minimum(new_q / jnp.maximum(capacity, 1e-6), latency_cap)
        return alpha * (lat * mask).sum() / n_active - gamma * served.sum()

    grad_fn = jax.grad(objective)

    def project(g):
        g = jnp.clip(g, floor, 1.0) * mask
        return _normalize_capacity(g, g_total)

    g0 = adaptive_allocation(lam, min_gpu, priority, g_total)
    g0 = jnp.where(busy.any(), g0, jnp.zeros_like(g0))

    def body(_, g):
        return project(g - lr * grad_fn(g))

    g = jax.lax.fori_loop(0, steps, body, project(g0))
    return jnp.where(busy.any(), g, jnp.zeros_like(g))


# ---------------------------------------------------------------------------
# Policy registry — the single dispatch table for the whole codebase.
#
# Each entry is a thin adapter over the pure functions above with the uniform
# signature ``(t, lam_obs, lam_ema, queue, fleet, g_total) -> g``; the pure
# functions stay faithful to Algorithm 1 and are still importable directly.
# ---------------------------------------------------------------------------

PolicyFn = Callable[..., jnp.ndarray]

_REGISTRY: dict[str, PolicyFn] = {}


def register_policy(name: str) -> Callable[[PolicyFn], PolicyFn]:
    """Register ``fn(t, lam_obs, lam_ema, queue, fleet, g_total) -> g``.

    Registration alone makes the policy reachable from ``simulate()``, the
    serving engine, and the sweep grid; registry order defines the stable
    integer policy id used by ``lax.switch``.
    """

    def deco(fn: PolicyFn) -> PolicyFn:
        if name in _REGISTRY:
            raise ValueError(f"policy {name!r} already registered")
        _REGISTRY[name] = fn
        return fn

    return deco


def policy_names() -> tuple[str, ...]:
    """All registered policies, in registration (= policy-id) order."""
    return tuple(_REGISTRY)


def policy_id(name: str) -> int:
    """Integer id of a registered policy (its index in ``policy_names()``)."""
    get_policy(name)
    return policy_names().index(name)


def get_policy(name: str) -> PolicyFn:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown policy {name!r}; registered policies: {policy_names()}"
        )
    return _REGISTRY[name]


def dispatch(
    name: str,
    t: jnp.ndarray,
    lam_obs: jnp.ndarray,
    lam_ema: jnp.ndarray,
    queue: jnp.ndarray,
    fleet: "Fleet",
    g_total: float = 1.0,
) -> jnp.ndarray:
    """Eager by-name dispatch (the serving-engine path)."""
    return get_policy(name)(t, lam_obs, lam_ema, queue, fleet, g_total)


def policy_switch(
    policy_id: jnp.ndarray,
    t: jnp.ndarray,
    lam_obs: jnp.ndarray,
    lam_ema: jnp.ndarray,
    queue: jnp.ndarray,
    fleet: "Fleet",
    g_total: float = 1.0,
    names: Sequence[str] | None = None,
) -> jnp.ndarray:
    """Traced dispatch over the registry (the simulator / sweep path).

    ``names`` pins the branch order for a jitted caller; it defaults to the
    live registry order.
    """
    names = policy_names() if names is None else tuple(names)
    branches = tuple(
        (lambda fn=_REGISTRY[n]: fn(t, lam_obs, lam_ema, queue, fleet, g_total))
        for n in names
    )
    return jax.lax.switch(policy_id, branches)


def policy_stack(
    t: jnp.ndarray,
    lam_obs: jnp.ndarray,
    lam_ema: jnp.ndarray,
    queue: jnp.ndarray,
    fleet: "Fleet",
    g_total,
    names: Sequence[str] | None = None,
) -> jnp.ndarray:
    """Evaluate each named policy exactly once on its own (P, N) state row.

    The streaming sweep kernel's dispatch (``simulator.simulate_stream_core``):
    the grid's policy axis is the name order, so instead of vmapping a
    ``lax.switch`` over policy ids — which lowers to evaluate-ALL-branches-
    and-select, P² allocator evaluations per grid — the registry is unrolled
    and policy ``names[i]`` sees only row ``i`` of the batched state.  O(P)
    policy evaluations per step, by construction.

    ``lam_obs`` / ``lam_ema`` / ``queue`` carry a leading policy axis (P, N);
    ``g_total`` is either one shared budget (python float or traced scalar)
    or a per-policy (P,) vector of traced warm-pool budgets (each policy row
    drives its own autoscaler trajectory under elastic capacity).
    """
    names = policy_names() if names is None else tuple(names)
    per_row_budget = jnp.ndim(g_total) == 1
    rows = []
    for i, name in enumerate(names):
        fn = get_policy(name)
        budget = g_total[i] if per_row_budget else g_total
        rows.append(fn(t, lam_obs[i], lam_ema[i], queue[i], fleet, budget))
    return jnp.stack(rows)


def policy_stack_blocks(
    t: jnp.ndarray,
    lam_obs: jnp.ndarray,
    lam_ema: jnp.ndarray,
    queue: jnp.ndarray,
    fleet: "Fleet",
    g_total,
    names: Sequence[str],
    num_blocks: int,
    block_index: jnp.ndarray,
) -> jnp.ndarray:
    """``policy_stack`` for ONE contiguous block of the name list, selected
    by a *traced* index — the policy-axis-sharded dispatch.

    Under ``shard_map`` every device traces the same program, so the static
    name unrolling of ``policy_stack`` cannot differ per device; what can is
    a ``lax.switch`` on ``lax.axis_index("policy")``.  Branch k statically
    unrolls name block k (policies ``names[k*p : (k+1)*p]``), so each device
    still evaluates each of its P/num_blocks policies exactly once per step
    — the O(P) dispatch guarantee survives the mesh split, and total trace
    cost across branches stays O(P).

    The state rows (``lam_obs``/``lam_ema``/``queue``, and ``g_total`` when
    per-row) are the **block-local** (P/num_blocks, N) rows, not the full
    stack — the caller already holds only its shard.
    """
    names = tuple(names)
    if num_blocks <= 0 or len(names) % num_blocks:
        raise ValueError(
            f"{len(names)} policies do not split into {num_blocks} equal blocks"
        )
    size = len(names) // num_blocks
    branches = tuple(
        (lambda group=names[k * size:(k + 1) * size]: policy_stack(
            t, lam_obs, lam_ema, queue, fleet, g_total, group
        ))
        for k in range(num_blocks)
    )
    return jax.lax.switch(block_index, branches)


# Every entry gates its inputs with ``fleet.active`` and hard-masks its
# output, so padded slots contribute zero demand and receive exactly g = 0.

@register_policy("static_equal")
def _static_equal_entry(t, lam_obs, lam_ema, queue, fleet, g_total):
    return masked_static_equal(fleet.active, g_total)


@register_policy("round_robin")
def _round_robin_entry(t, lam_obs, lam_ema, queue, fleet, g_total):
    return masked_round_robin(t, fleet.active, g_total)


@register_policy("adaptive")
def _adaptive_entry(t, lam_obs, lam_ema, queue, fleet, g_total):
    m = fleet.active
    return adaptive_allocation(lam_obs * m, fleet.min_gpu * m, fleet.priority, g_total) * m


@register_policy("water_filling")
def _water_filling_entry(t, lam_obs, lam_ema, queue, fleet, g_total):
    m = fleet.active
    return water_filling(
        queue * m, lam_obs * m, fleet.base_throughput, fleet.min_gpu * m, g_total
    ) * m


@register_policy("predictive")
def _predictive_entry(t, lam_obs, lam_ema, queue, fleet, g_total):
    m = fleet.active
    return predictive_adaptive(lam_ema * m, fleet.min_gpu * m, fleet.priority, g_total) * m


@register_policy("throughput_greedy")
def _throughput_greedy_entry(t, lam_obs, lam_ema, queue, fleet, g_total):
    m = fleet.active
    return throughput_greedy(
        queue * m, lam_obs * m, fleet.base_throughput, fleet.min_gpu * m, g_total
    ) * m


@register_policy("objective_descent")
def _objective_descent_entry(t, lam_obs, lam_ema, queue, fleet, g_total):
    m = fleet.active
    return objective_descent(
        queue * m, lam_obs * m, fleet.base_throughput, fleet.min_gpu * m,
        fleet.priority, g_total, active=m,
    ) * m


@register_policy("sqrt_demand")
def _sqrt_demand_entry(t, lam_obs, lam_ema, queue, fleet, g_total):
    m = fleet.active
    return sqrt_demand(
        queue * m, lam_obs * m, fleet.base_throughput, fleet.min_gpu * m, g_total
    ) * m


@register_policy("ema_water_filling")
def _ema_water_filling_entry(t, lam_obs, lam_ema, queue, fleet, g_total):
    m = fleet.active
    return ema_water_filling(
        queue * m, lam_ema * m, fleet.base_throughput, fleet.min_gpu * m, g_total
    ) * m


def __getattr__(attr: str):
    # POLICY_NAMES is derived from the registry, never hand-maintained.
    if attr == "POLICY_NAMES":
        return policy_names()
    raise AttributeError(f"module {__name__!r} has no attribute {attr!r}")
