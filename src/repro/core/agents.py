"""Agent fleet specifications (paper §III-A, Table I).

An agent is characterized by (M_i, T_i, R_i, P_i): model size (MB), base
throughput at full GPU (requests/s), minimum GPU fraction, and priority
(1 = high, 2 = medium, 3 = low).  The fleet is stored struct-of-arrays so the
allocator and simulator are fully vectorized jnp (O(N), jittable).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AgentSpec:
    """One agent's static profile (paper Table I row)."""

    name: str
    model_size_mb: float   # M_i
    base_throughput: float  # T_i, requests/s at g=1.0
    min_gpu: float          # R_i, fraction of total capacity
    priority: int           # P_i: 1=high, 2=medium, 3=low


@dataclasses.dataclass(frozen=True)
class Fleet:
    """Struct-of-arrays view of N agents, ready for vectorized allocation."""

    names: tuple[str, ...]
    model_size_mb: jnp.ndarray   # (N,)
    base_throughput: jnp.ndarray  # (N,)
    min_gpu: jnp.ndarray          # (N,)
    priority: jnp.ndarray         # (N,) float for jnp division

    @property
    def num_agents(self) -> int:
        return len(self.names)

    @staticmethod
    def from_specs(specs: Sequence[AgentSpec]) -> "Fleet":
        return Fleet(
            names=tuple(s.name for s in specs),
            model_size_mb=jnp.asarray([s.model_size_mb for s in specs], jnp.float32),
            base_throughput=jnp.asarray([s.base_throughput for s in specs], jnp.float32),
            min_gpu=jnp.asarray([s.min_gpu for s in specs], jnp.float32),
            priority=jnp.asarray([s.priority for s in specs], jnp.float32),
        )

    def validate(self) -> None:
        """Static sanity constraints (checked eagerly, outside jit)."""
        mins = np.asarray(self.min_gpu)
        pris = np.asarray(self.priority)
        if (mins < 0).any() or (mins > 1).any():
            raise ValueError(f"min_gpu out of [0,1]: {mins}")
        if (pris < 1).any():
            raise ValueError(f"priority must be >= 1: {pris}")
        if (np.asarray(self.base_throughput) <= 0).any():
            raise ValueError("base_throughput must be positive")


def paper_fleet() -> Fleet:
    """The paper's 4-agent system, exactly Table I."""
    return Fleet.from_specs([
        AgentSpec("coordinator", 500.0, 100.0, 0.10, 1),
        AgentSpec("specialist_nlp", 2000.0, 50.0, 0.30, 2),
        AgentSpec("specialist_vision", 1500.0, 60.0, 0.25, 2),
        AgentSpec("specialist_reasoning", 3000.0, 30.0, 0.35, 1),
    ])


# Paper §IV-A arrival rates (requests/second).
PAPER_ARRIVAL_RATES = (80.0, 40.0, 45.0, 25.0)

# Paper platform model: NVIDIA T4, $0.72/hour.
T4_PRICE_PER_HOUR = 0.72
