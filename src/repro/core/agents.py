"""Agent fleet specifications (paper §III-A, Table I) as a JAX pytree.

An agent is characterized by (M_i, T_i, R_i, P_i): model size (MB), base
throughput at full GPU (requests/s), minimum GPU fraction, and priority
(1 = high, 2 = medium, 3 = low).  The fleet is stored struct-of-arrays so the
allocator and simulator are fully vectorized jnp (O(N), jittable).

``Fleet`` is a **registered pytree**: the numeric arrays (including the
``active`` validity mask) are leaves and the ``names`` tuple is static aux
data, so fleets flow directly through ``jax.jit`` / ``jax.vmap`` /
``jax.device_put`` with no array/static plumbing at call sites.  The mask is
what makes *batches of heterogeneous fleet sizes* one array program:

* every fleet carries ``active`` ∈ {0,1}^N; real agents are 1, padding is 0;
* ``pad_fleet`` grows a fleet to ``n_max`` slots with inert padding
  (T=1, R=0, P=1, active=0) — policies give padded slots exactly g = 0 and
  metric reductions ignore them (see ``core/allocator.py`` /
  ``core/simulator.py``);
* ``stack_fleets`` pads a list of fleets to a common width and stacks every
  leaf along a new leading fleet axis, ready for ``vmap`` over fleets
  (``core/sweep.py::sweep_fleets``).

Generators: ``paper_fleet()`` is the paper's exact Table I; ``scale_fleet``
tiles it to N agents (min-GPU rescaled so Σ R_i is preserved);
``synthetic_fleet(n, seed)`` draws a reproducible random heterogeneous fleet
for agent-count scaling studies.

``Fleet`` describes *who* the agents are; its sibling pytree ``Workflow``
(``core/routing.py``) describes how requests flow *between* them.  The two
pad consistently: ``pad_workflow`` keeps the routing matrix aligned with
``pad_fleet``'s ``active`` mask, so padded slots neither receive nor
forward routed traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AgentSpec:
    """One agent's static profile (paper Table I row)."""

    name: str
    model_size_mb: float   # M_i
    base_throughput: float  # T_i, requests/s at g=1.0
    min_gpu: float          # R_i, fraction of total capacity
    priority: int           # P_i: 1=high, 2=medium, 3=low


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Fleet:
    """Struct-of-arrays view of N agent slots, ready for jit/vmap.

    ``active`` is the agent-validity mask: 1.0 for real agents, 0.0 for
    padding slots introduced by ``pad_fleet``/``stack_fleets``.  It defaults
    to all-ones, so single unpadded fleets behave exactly as before.
    """

    names: tuple[str, ...]
    model_size_mb: jnp.ndarray   # (N,)
    base_throughput: jnp.ndarray  # (N,)
    min_gpu: jnp.ndarray          # (N,)
    priority: jnp.ndarray         # (N,) float for jnp division
    active: jnp.ndarray = None    # (N,) validity mask, defaults to ones

    def __post_init__(self):
        if self.active is None:
            object.__setattr__(
                self, "active", jnp.ones(len(self.names), jnp.float32)
            )

    # -- pytree protocol: arrays are leaves, names are static aux data. ------

    def tree_flatten(self):
        children = (self.model_size_mb, self.base_throughput,
                    self.min_gpu, self.priority, self.active)
        return children, self.names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(names, *children)

    @property
    def num_agents(self) -> int:
        """Static slot count N (padded width; use ``num_active`` for a
        traced count of real agents)."""
        return len(self.names)

    @property
    def num_active(self) -> jnp.ndarray:
        """Traced number of real (non-padding) agents."""
        return self.active.sum()

    @staticmethod
    def from_specs(specs: Sequence[AgentSpec]) -> "Fleet":
        return Fleet(
            names=tuple(s.name for s in specs),
            model_size_mb=jnp.asarray([s.model_size_mb for s in specs], jnp.float32),
            base_throughput=jnp.asarray([s.base_throughput for s in specs], jnp.float32),
            min_gpu=jnp.asarray([s.min_gpu for s in specs], jnp.float32),
            priority=jnp.asarray([s.priority for s in specs], jnp.float32),
        )

    def validate(self) -> None:
        """Static sanity constraints (checked eagerly, outside jit)."""
        mins = np.asarray(self.min_gpu)
        pris = np.asarray(self.priority)
        mask = np.asarray(self.active)
        if (mins < 0).any() or (mins > 1).any():
            raise ValueError(f"min_gpu out of [0,1]: {mins}")
        if (pris < 1).any():
            raise ValueError(f"priority must be >= 1: {pris}")
        if (np.asarray(self.base_throughput) <= 0).any():
            raise ValueError("base_throughput must be positive")
        if not np.isin(mask, (0.0, 1.0)).all():
            raise ValueError(f"active mask must be 0/1: {mask}")


def paper_fleet() -> Fleet:
    """The paper's 4-agent system, exactly Table I."""
    return Fleet.from_specs([
        AgentSpec("coordinator", 500.0, 100.0, 0.10, 1),
        AgentSpec("specialist_nlp", 2000.0, 50.0, 0.30, 2),
        AgentSpec("specialist_vision", 1500.0, 60.0, 0.25, 2),
        AgentSpec("specialist_reasoning", 3000.0, 30.0, 0.35, 1),
    ])


def scale_fleet(fleet: Fleet, n: int) -> Fleet:
    """Tile ``fleet`` to ``n`` agents, preserving total minimum guarantees.

    Agent k inherits the profile of ``fleet`` agent ``k % N``; the tiled
    ``min_gpu`` vector is renormalized to the *original* Σ R_i (computed
    from the actual tiled sum, so partial tiles are handled exactly) — the
    fleet stays schedulable under the same G_total at any size.
    """
    base = fleet.num_agents
    if n < 1:
        raise ValueError(f"fleet size must be >= 1, got {n}")
    if (np.asarray(fleet.active) != 1.0).any():
        raise ValueError(
            "scale_fleet needs an unpadded fleet; tiling masked slots would "
            "resurrect padding as real agents"
        )
    idx = np.arange(n) % base
    take = lambda a: np.asarray(a, np.float32)[idx]
    mins = take(fleet.min_gpu)
    target = float(np.asarray(fleet.min_gpu, np.float32).sum())
    if mins.sum() > 0:
        mins = mins * (target / mins.sum())
    return Fleet(
        names=tuple(f"{fleet.names[i]}_{k}" for k, i in enumerate(idx)),
        model_size_mb=jnp.asarray(take(fleet.model_size_mb)),
        base_throughput=jnp.asarray(take(fleet.base_throughput)),
        min_gpu=jnp.asarray(mins),
        priority=jnp.asarray(take(fleet.priority)),
    )


def synthetic_fleet(n: int, seed: int = 0, total_min_gpu: float = 0.8) -> Fleet:
    """A reproducible random heterogeneous fleet of ``n`` agents.

    Profiles are drawn in the paper's Table I ranges; minimum guarantees are
    random proportions normalized so Σ R_i == ``total_min_gpu`` regardless of
    ``n``, keeping every size schedulable under G_total = 1.
    """
    if n < 1:
        raise ValueError(f"fleet size must be >= 1, got {n}")
    rng = np.random.default_rng(seed)
    mins = rng.uniform(0.5, 1.5, n)
    mins = total_min_gpu * mins / mins.sum()
    return Fleet(
        names=tuple(f"agent_{i:03d}" for i in range(n)),
        model_size_mb=jnp.asarray(rng.uniform(250.0, 4000.0, n), jnp.float32),
        base_throughput=jnp.asarray(rng.uniform(20.0, 120.0, n), jnp.float32),
        min_gpu=jnp.asarray(mins, jnp.float32),
        priority=jnp.asarray(rng.integers(1, 4, n), jnp.float32),
    )


def pad_fleet(fleet: Fleet, n_max: int) -> Fleet:
    """Pad ``fleet`` to ``n_max`` slots with inert, masked-out agents.

    Padding slots carry T=1 (keeps ``base_throughput > 0`` valid and all
    divisions finite), R=0, P=1 and ``active=0``; every registered policy
    hands them exactly g = 0 and the simulator's reductions skip them.
    """
    n = fleet.num_agents
    if n_max < n:
        raise ValueError(f"cannot pad fleet of {n} agents down to {n_max}")
    if n_max == n:
        return fleet
    pad = n_max - n

    def ext(a, fill):
        return jnp.concatenate(
            [jnp.asarray(a, jnp.float32), jnp.full((pad,), fill, jnp.float32)]
        )

    return Fleet(
        names=fleet.names + tuple(f"_pad_{i}" for i in range(pad)),
        model_size_mb=ext(fleet.model_size_mb, 0.0),
        base_throughput=ext(fleet.base_throughput, 1.0),
        min_gpu=ext(fleet.min_gpu, 0.0),
        priority=ext(fleet.priority, 1.0),
        active=ext(fleet.active, 0.0),
    )


def stack_fleets(fleets: Sequence[Fleet], n_max: int | None = None) -> Fleet:
    """Pad ``fleets`` to a common width and stack each leaf on a new leading
    fleet axis: every array becomes (F, N_max) and ``names`` collapse to
    generic slot labels (per-fleet names differ, so they cannot be aux data
    of one batched pytree).  The result vmaps directly over axis 0.
    """
    if not fleets:
        raise ValueError("stack_fleets needs at least one fleet")
    width = max(f.num_agents for f in fleets)
    n_max = width if n_max is None else n_max
    if n_max < width:
        raise ValueError(f"n_max={n_max} < widest fleet ({width} agents)")
    padded = [pad_fleet(f, n_max) for f in fleets]
    stack = lambda field: jnp.stack([getattr(f, field) for f in padded])
    return Fleet(
        names=tuple(f"slot_{i:03d}" for i in range(n_max)),
        model_size_mb=stack("model_size_mb"),
        base_throughput=stack("base_throughput"),
        min_gpu=stack("min_gpu"),
        priority=stack("priority"),
        active=stack("active"),
    )


# Paper §IV-A arrival rates (requests/second).
PAPER_ARRIVAL_RATES = (80.0, 40.0, 45.0, 25.0)

# Paper platform model: NVIDIA T4, $0.72/hour.
T4_PRICE_PER_HOUR = 0.72
