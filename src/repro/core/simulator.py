"""Discrete-time fleet simulator (paper §IV-B), pure JAX ``lax.scan``.

Semantics reconstructed from the paper (DESIGN.md §6):

* one-second timesteps; requests arrive, the allocator distributes the GPU,
  agents serve ``min(g_i·T_i, queue_i + arrivals_i)`` (throughput scales
  proportionally with allocation), leftovers carry over FIFO;
* per-step latency estimate is the Little's-law drain time of the post-step
  queue at the *current* service rate, clipped at ``latency_cap`` seconds —
  a starved agent (g=0, e.g. off-turn under round-robin) reports the cap.
  This clipping is what produces the paper's round-robin figure of
  756.1 s ≈ 0.75·1000 + on-turn drain; we reproduce it faithfully and also
  expose the unclipped long-run latency (``littles_law_latency``);
* cost is the provisioned-device cost: duration · price/hour — identical
  across policies, as in Table II.

The whole run is one ``lax.scan``; policies are selected with ``lax.switch``
so a (policies × workloads) sweep can be ``vmap``-ed.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import allocator as alloc
from repro.core.agents import Fleet, T4_PRICE_PER_HOUR

_EPS = 1e-9

# Integer policy ids, stable across the codebase (== index in POLICY_NAMES).
POLICY_IDS = {name: i for i, name in enumerate(alloc.POLICY_NAMES)}


@dataclasses.dataclass(frozen=True)
class SimConfig:
    num_steps: int = 100
    g_total: float = 1.0
    latency_cap: float = 1000.0
    price_per_hour: float = T4_PRICE_PER_HOUR
    num_gpus: float = 1.0
    ema_alpha: float = 0.3


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimTrace:
    """Per-step, per-agent trajectories: everything Fig. 2 plots."""

    allocation: jnp.ndarray  # (S, N) g_i(t)
    served: jnp.ndarray      # (S, N) requests served in step t
    queue: jnp.ndarray       # (S, N) backlog after step t
    latency: jnp.ndarray     # (S, N) clipped drain-time estimate
    arrivals: jnp.ndarray    # (S, N)

    def tree_flatten(self):
        return (self.allocation, self.served, self.queue, self.latency, self.arrivals), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class SimSummary:
    """Table II row for one policy."""

    policy: str
    avg_latency: float
    latency_std: float          # std across agents' mean latencies (Table II)
    per_agent_latency: tuple
    total_throughput: float     # served requests / second
    per_agent_throughput: tuple
    cost: float                 # provisioned $ for the run
    gpu_utilization: float      # mean Σ g_i
    littles_law_latency: float  # unclipped long-run estimate
    mean_queue: float


def _policy_step(
    policy_id: jnp.ndarray,
    t: jnp.ndarray,
    lam_obs: jnp.ndarray,
    lam_ema: jnp.ndarray,
    queue: jnp.ndarray,
    fleet: Fleet,
    g_total: float,
) -> jnp.ndarray:
    n = fleet.num_agents
    branches = (
        lambda: alloc.static_equal(n, g_total),
        lambda: alloc.round_robin(t, n, g_total),
        lambda: alloc.adaptive_allocation(lam_obs, fleet.min_gpu, fleet.priority, g_total),
        lambda: alloc.water_filling(queue, lam_obs, fleet.base_throughput, fleet.min_gpu, g_total),
        lambda: alloc.predictive_adaptive(lam_ema, fleet.min_gpu, fleet.priority, g_total),
        lambda: alloc.throughput_greedy(queue, lam_obs, fleet.base_throughput, fleet.min_gpu, g_total),
        lambda: alloc.objective_descent(queue, lam_obs, fleet.base_throughput,
                                        fleet.min_gpu, fleet.priority, g_total),
    )
    return jax.lax.switch(policy_id, branches)


@functools.partial(jax.jit, static_argnames=("fleet_static", "config"))
def _simulate_jit(
    policy_id: jnp.ndarray,
    arrivals: jnp.ndarray,
    fleet_arrays: tuple,
    fleet_static: tuple,
    config: SimConfig,
) -> SimTrace:
    fleet = Fleet(fleet_static, *fleet_arrays)

    def step(carry, inp):
        queue, lam_ema = carry
        t, lam = inp
        lam_ema = alloc.ema_forecast(lam_ema, lam, config.ema_alpha)
        g = _policy_step(policy_id, t, lam, lam_ema, queue, fleet, config.g_total)
        capacity = g * fleet.base_throughput
        served = jnp.minimum(capacity, queue + lam)
        new_queue = queue + lam - served
        latency = jnp.minimum(
            new_queue / jnp.maximum(capacity, _EPS), config.latency_cap
        )
        return (new_queue, lam_ema), (g, served, new_queue, latency)

    num_steps = arrivals.shape[0]
    ts = jnp.arange(num_steps)
    init = (jnp.zeros(fleet.num_agents, jnp.float32), arrivals[0])
    (_, _), (g, served, queue, latency) = jax.lax.scan(step, init, (ts, arrivals))
    return SimTrace(g, served, queue, latency, arrivals)


def simulate(
    policy: str,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig = SimConfig(),
) -> SimTrace:
    """Run one policy over an (S, N) arrival matrix."""
    fleet.validate()
    arrays = (fleet.model_size_mb, fleet.base_throughput, fleet.min_gpu, fleet.priority)
    return _simulate_jit(
        jnp.asarray(POLICY_IDS[policy]), arrivals, arrays, fleet.names, config
    )


def summarize(policy: str, trace: SimTrace, config: SimConfig = SimConfig()) -> SimSummary:
    """Table II metrics from a trace."""
    per_agent_lat = trace.latency.mean(axis=0)
    per_agent_tput = trace.served.mean(axis=0)
    duration_s = trace.served.shape[0]
    cost = config.num_gpus * duration_s / 3600.0 * config.price_per_hour
    # Unclipped long-run latency: mean backlog over long-run service rate.
    longrun_rate = jnp.maximum(trace.served.mean(axis=0), _EPS)
    littles = (trace.queue.mean(axis=0) / longrun_rate).mean()
    return SimSummary(
        policy=policy,
        avg_latency=float(per_agent_lat.mean()),
        latency_std=float(per_agent_lat.std()),
        per_agent_latency=tuple(float(x) for x in per_agent_lat),
        total_throughput=float(per_agent_tput.sum()),
        per_agent_throughput=tuple(float(x) for x in per_agent_tput),
        cost=float(cost),
        gpu_utilization=float(trace.allocation.sum(axis=1).mean()),
        littles_law_latency=float(littles),
        mean_queue=float(trace.queue.mean()),
    )


def run_policy(
    policy: str,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig = SimConfig(),
) -> SimSummary:
    return summarize(policy, simulate(policy, arrivals, fleet, config), config)
