"""Discrete-time fleet simulator (paper §IV-B), pure JAX ``lax.scan``.

Semantics reconstructed from the paper (DESIGN.md §6):

* one-second timesteps; requests arrive, the allocator distributes the GPU,
  agents serve ``min(g_i·T_i, queue_i + arrivals_i)`` (throughput scales
  proportionally with allocation), leftovers carry over FIFO;
* per-step latency estimate is the Little's-law drain time of the post-step
  queue at the *current* service rate, clipped at ``latency_cap`` seconds —
  a starved agent (g=0, e.g. off-turn under round-robin) reports the cap.
  This clipping is what produces the paper's round-robin figure of
  756.1 s ≈ 0.75·1000 + on-turn drain; we reproduce it faithfully and also
  expose the unclipped long-run latency (``littles_law_latency``);
* cost is the provisioned-device cost: duration · price/hour — identical
  across policies, as in Table II.

The whole run is one ``lax.scan``; policies are selected with ``lax.switch``
built from the allocator's policy registry, and ``Fleet`` is a registered
pytree, so a (fleets × policies × workloads) sweep is plain nested ``vmap``
— see ``core/sweep.py`` for the grid runner.  Padded fleets are first-class:
arrivals are gated by ``fleet.active`` and every metric reduction is
mask-weighted, so a padded fleet reports the same numbers as its unpadded
original.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import allocator as alloc
from repro.core.agents import Fleet, T4_PRICE_PER_HOUR

_EPS = 1e-9


def __getattr__(attr: str):
    # Back-compat alias; the registry is authoritative (alloc.policy_id).
    if attr == "POLICY_IDS":
        return {name: i for i, name in enumerate(alloc.policy_names())}
    raise AttributeError(f"module {__name__!r} has no attribute {attr!r}")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    num_steps: int = 100
    g_total: float = 1.0
    latency_cap: float = 1000.0
    price_per_hour: float = T4_PRICE_PER_HOUR
    num_gpus: float = 1.0
    ema_alpha: float = 0.3


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimTrace:
    """Per-step, per-agent trajectories: everything Fig. 2 plots."""

    allocation: jnp.ndarray  # (S, N) g_i(t)
    served: jnp.ndarray      # (S, N) requests served in step t
    queue: jnp.ndarray       # (S, N) backlog after step t
    latency: jnp.ndarray     # (S, N) clipped drain-time estimate
    arrivals: jnp.ndarray    # (S, N)

    def tree_flatten(self):
        return (self.allocation, self.served, self.queue, self.latency, self.arrivals), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class SimSummary:
    """Table II row for one policy."""

    policy: str
    avg_latency: float
    latency_std: float          # std across agents' mean latencies (Table II)
    per_agent_latency: tuple
    total_throughput: float     # served requests / second
    per_agent_throughput: tuple
    cost: float                 # provisioned $ for the run
    gpu_utilization: float      # mean Σ g_i
    littles_law_latency: float  # unclipped long-run estimate
    mean_queue: float


def simulate_core(
    policy_id: jnp.ndarray,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig,
    policy_names: Sequence[str] | None = None,
) -> SimTrace:
    """Pure scan body — jit/vmap-able over ``policy_id``, ``arrivals`` and
    the ``fleet`` pytree (including a batched fleet axis).

    The EMA carry is seeded with the first observation; the update is skipped
    at t=0 so that observation is not applied twice.  Arrivals are gated by
    ``fleet.active`` so padding slots never accumulate queue.
    """
    names = alloc.policy_names() if policy_names is None else tuple(policy_names)
    arrivals = arrivals * fleet.active

    def step(carry, inp):
        queue, lam_ema = carry
        t, lam = inp
        lam_ema = jnp.where(
            t > 0, alloc.ema_forecast(lam_ema, lam, config.ema_alpha), lam_ema
        )
        g = alloc.policy_switch(
            policy_id, t, lam, lam_ema, queue, fleet, config.g_total, names
        )
        capacity = g * fleet.base_throughput
        served = jnp.minimum(capacity, queue + lam)
        new_queue = queue + lam - served
        latency = jnp.minimum(
            new_queue / jnp.maximum(capacity, _EPS), config.latency_cap
        )
        return (new_queue, lam_ema), (g, served, new_queue, latency)

    num_steps = arrivals.shape[0]
    ts = jnp.arange(num_steps)
    init = (jnp.zeros(fleet.num_agents, jnp.float32), arrivals[0])
    (_, _), (g, served, queue, latency) = jax.lax.scan(step, init, (ts, arrivals))
    return SimTrace(g, served, queue, latency, arrivals)


# ``Fleet`` is a registered pytree (names are static aux data), so it passes
# straight through jit — no array/static plumbing.
_simulate_jit = jax.jit(simulate_core, static_argnames=("config", "policy_names"))


def simulate(
    policy: str,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig = SimConfig(),
) -> SimTrace:
    """Run one registered policy over an (S, N) arrival matrix."""
    fleet.validate()
    return _simulate_jit(
        jnp.asarray(alloc.policy_id(policy)), arrivals, fleet, config,
        alloc.policy_names(),
    )


# Order of the metric vector returned by trace_metrics (and of the metric
# axis in sweep grids).
METRIC_NAMES = (
    "avg_latency",
    "latency_std",
    "total_throughput",
    "gpu_utilization",
    "mean_queue",
    "littles_law_latency",
)


def trace_metrics(
    trace: SimTrace, active: jnp.ndarray | None = None
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Table II reductions for one trace, jit/vmap-safe.

    Returns (metric vector in METRIC_NAMES order, per-agent mean latency,
    per-agent mean throughput).  The single definition behind both
    ``summarize`` and the sweep grid.

    ``active`` is the fleet's validity mask: per-agent means/stds weight by
    it, so padded slots (latency 0, throughput 0) never dilute the metrics.
    With the default all-ones mask this is exactly the unweighted reduction.
    """
    m = jnp.ones(trace.latency.shape[-1]) if active is None else active
    n_active = jnp.maximum(m.sum(), 1.0)
    mmean = lambda x: (x * m).sum() / n_active  # masked mean over agents
    per_lat = trace.latency.mean(axis=0)
    per_tput = trace.served.mean(axis=0)
    # Unclipped long-run latency: mean backlog over long-run service rate.
    longrun_rate = jnp.maximum(per_tput, _EPS)
    littles = mmean(trace.queue.mean(axis=0) / longrun_rate)
    lat_mean = mmean(per_lat)
    lat_std = jnp.sqrt(mmean((per_lat - lat_mean) ** 2))
    vec = jnp.stack([
        lat_mean,
        lat_std,
        per_tput.sum(),
        trace.allocation.sum(axis=1).mean(),
        mmean(trace.queue.mean(axis=0)),
        littles,
    ])
    return vec, per_lat, per_tput


def summarize(
    policy: str,
    trace: SimTrace,
    config: SimConfig = SimConfig(),
    active: jnp.ndarray | None = None,
) -> SimSummary:
    """Table II metrics from a trace (``active`` masks padded agents)."""
    vec, per_agent_lat, per_agent_tput = trace_metrics(trace, active)
    duration_s = trace.served.shape[0]
    cost = config.num_gpus * duration_s / 3600.0 * config.price_per_hour
    m = dict(zip(METRIC_NAMES, (float(x) for x in vec)))
    return SimSummary(
        policy=policy,
        avg_latency=m["avg_latency"],
        latency_std=m["latency_std"],
        per_agent_latency=tuple(float(x) for x in per_agent_lat),
        total_throughput=m["total_throughput"],
        per_agent_throughput=tuple(float(x) for x in per_agent_tput),
        cost=float(cost),
        gpu_utilization=m["gpu_utilization"],
        littles_law_latency=m["littles_law_latency"],
        mean_queue=m["mean_queue"],
    )


def run_policy(
    policy: str,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig = SimConfig(),
) -> SimSummary:
    return summarize(
        policy, simulate(policy, arrivals, fleet, config), config, fleet.active
    )
