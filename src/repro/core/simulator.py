"""Discrete-time fleet simulator (paper §IV-B), pure JAX ``lax.scan``.

Semantics reconstructed from the paper (DESIGN.md §6):

* one-second timesteps; requests arrive, the allocator distributes the GPU,
  agents serve ``min(g_i·T_i, queue_i + arrivals_i)`` (throughput scales
  proportionally with allocation), leftovers carry over FIFO;
* per-step latency estimate is the Little's-law drain time of the post-step
  queue at the *current* service rate, clipped at ``latency_cap`` seconds —
  a starved agent (g=0, e.g. off-turn under round-robin) reports the cap.
  This clipping is what produces the paper's round-robin figure of
  756.1 s ≈ 0.75·1000 + on-turn drain; we reproduce it faithfully and also
  expose the unclipped long-run latency (``littles_law_latency``);
* cost is the provisioned-device cost: duration · price/hour — identical
  across policies, as in Table II.

**Workflow routing** (``core/routing.py``) makes the multi-agent dataflow
itself part of the dynamics: each step's *served* requests at agent i are
routed into downstream queues for step t+1
(``arrivals_endogenous = (served * fan_out) @ route``), exogenous
generators feed only ``workflow.source`` agents, and the row deficit of the
routing matrix exits the workflow as completed end-to-end requests
(``SimTrace.completed``).  Policies observe the *total* intake — exogenous
plus endogenous — so queue-pressure and rate-driven allocators both react
to collaborative cascades.  With no workflow (or ``routing.independent``)
the endogenous term is identically zero and trajectories are bit-for-bit
what they were before routing existed.

The whole run is one ``lax.scan``; policies are selected with ``lax.switch``
built from the allocator's policy registry, and ``Fleet`` / ``Workflow``
are registered pytrees, so a (fleets × policies × workloads) or
(workflows × policies × workloads) sweep is plain nested ``vmap`` — see
``core/sweep.py`` for the grid runners.  Padded fleets are first-class:
arrivals are gated by ``fleet.active`` and every metric reduction is
mask-weighted, so a padded fleet reports the same numbers as its unpadded
original.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import allocator as alloc
from repro.core.agents import Fleet, T4_PRICE_PER_HOUR
from repro.core.routing import Workflow, check_workflow

_EPS = 1e-9


def __getattr__(attr: str):
    # Back-compat alias; the registry is authoritative (alloc.policy_id).
    if attr == "POLICY_IDS":
        return {name: i for i, name in enumerate(alloc.policy_names())}
    raise AttributeError(f"module {__name__!r} has no attribute {attr!r}")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    num_steps: int = 100
    g_total: float = 1.0
    latency_cap: float = 1000.0
    price_per_hour: float = T4_PRICE_PER_HOUR
    num_gpus: float = 1.0
    ema_alpha: float = 0.3


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimTrace:
    """Per-step, per-agent trajectories: everything Fig. 2 plots.

    ``arrivals`` records the *exogenous* input (gated by the workflow's
    source flags and the fleet's active mask); ``completed`` the requests
    that exited the workflow at each agent (= served, when no workflow
    routes traffic).  The difference between served and completed is the
    endogenous traffic forwarded downstream.
    """

    allocation: jnp.ndarray  # (S, N) g_i(t)
    served: jnp.ndarray      # (S, N) requests served in step t
    queue: jnp.ndarray       # (S, N) backlog after step t
    latency: jnp.ndarray     # (S, N) clipped drain-time estimate
    arrivals: jnp.ndarray    # (S, N) exogenous arrivals (source-gated)
    completed: jnp.ndarray = None  # (S, N) requests exiting the workflow

    def __post_init__(self):
        if self.completed is None:
            self.completed = self.served

    def tree_flatten(self):
        return (self.allocation, self.served, self.queue, self.latency,
                self.arrivals, self.completed), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class SimSummary:
    """Table II row for one policy."""

    policy: str
    avg_latency: float
    latency_std: float          # std across agents' mean latencies (Table II)
    per_agent_latency: tuple
    total_throughput: float     # served requests / second
    per_agent_throughput: tuple
    cost: float                 # provisioned $ for the run
    gpu_utilization: float      # mean Σ g_i
    littles_law_latency: float  # unclipped long-run estimate
    mean_queue: float
    # Workflow (end-to-end) metrics; equal their per-agent analogues when no
    # workflow routes traffic.
    sink_throughput: float = 0.0        # requests exiting the workflow / s
    critical_path_latency: float = 0.0  # longest source→sink latency chain
    per_agent_queue: tuple = ()         # per-stage mean backlog

    @classmethod
    def from_metrics(
        cls,
        policy: str,
        m: dict,
        per_agent_latency,
        per_agent_throughput,
        per_agent_queue,
        cost: float,
    ) -> "SimSummary":
        """The one METRIC_NAMES-dict → summary mapping, shared by
        ``summarize`` and ``SweepResult.summary`` so a new metric cannot be
        threaded through one path and silently default on the other."""
        return cls(
            policy=policy,
            avg_latency=m["avg_latency"],
            latency_std=m["latency_std"],
            per_agent_latency=tuple(float(x) for x in per_agent_latency),
            total_throughput=m["total_throughput"],
            per_agent_throughput=tuple(float(x) for x in per_agent_throughput),
            cost=float(cost),
            gpu_utilization=m["gpu_utilization"],
            littles_law_latency=m["littles_law_latency"],
            mean_queue=m["mean_queue"],
            sink_throughput=m["sink_throughput"],
            critical_path_latency=m["critical_path_latency"],
            per_agent_queue=tuple(float(x) for x in per_agent_queue),
        )


def simulate_core(
    policy_id: jnp.ndarray,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig,
    policy_names: Sequence[str] | None = None,
    workflow: Workflow | None = None,
) -> SimTrace:
    """Pure scan body — jit/vmap-able over ``policy_id``, ``arrivals``, the
    ``fleet`` pytree and the ``workflow`` pytree (both may carry a batch
    axis).

    The EMA carry is seeded with the first observation; the update is skipped
    at t=0 so that observation is not applied twice.  Exogenous arrivals are
    gated by ``fleet.active`` (padding slots never accumulate queue) and by
    ``workflow.source`` (only source agents see outside traffic); each
    step's served requests are fanned into downstream queues for the next
    step via the routing matrix.  With ``workflow=None`` the endogenous
    path contributes exact zeros — trajectories are bit-for-bit identical
    to the pre-routing simulator.
    """
    names = alloc.policy_names() if policy_names is None else tuple(policy_names)
    n = fleet.num_agents
    if workflow is None:
        route = jnp.zeros((n, n), jnp.float32)
        source = jnp.ones(n, jnp.float32)
        fan_out = jnp.ones(n, jnp.float32)
    else:
        route, source, fan_out = workflow.route, workflow.source, workflow.fan_out
    arrivals = arrivals * fleet.active * source
    route_eff = route * fan_out[..., :, None]   # forwarded copies
    exit_frac = jnp.maximum(1.0 - route.sum(axis=-1), 0.0)

    def step(carry, inp):
        queue, lam_ema, endo = carry
        t, lam_exo = inp
        lam = lam_exo + endo            # total intake: exogenous + routed
        lam_ema = jnp.where(
            t > 0, alloc.ema_forecast(lam_ema, lam, config.ema_alpha), lam_ema
        )
        g = alloc.policy_switch(
            policy_id, t, lam, lam_ema, queue, fleet, config.g_total, names
        )
        capacity = g * fleet.base_throughput
        served = jnp.minimum(capacity, queue + lam)
        new_queue = queue + lam - served
        latency = jnp.minimum(
            new_queue / jnp.maximum(capacity, _EPS), config.latency_cap
        )
        completed = served * exit_frac  # row deficit exits the workflow
        # Routed mass arrives downstream next step; the active gate keeps
        # padded slots inert even if a route column points at one (the
        # misrouted mass is dropped, exactly like gated exogenous traffic).
        new_endo = (served @ route_eff) * fleet.active
        return (new_queue, lam_ema, new_endo), (g, served, new_queue, latency, completed)

    num_steps = arrivals.shape[0]
    ts = jnp.arange(num_steps)
    init = (
        jnp.zeros(n, jnp.float32),
        arrivals[0],
        jnp.zeros(n, jnp.float32),
    )
    _, (g, served, queue, latency, completed) = jax.lax.scan(
        step, init, (ts, arrivals)
    )
    return SimTrace(g, served, queue, latency, arrivals, completed)


# ``Fleet`` and ``Workflow`` are registered pytrees (names are static aux
# data), so they pass straight through jit — no array/static plumbing.
_simulate_jit = jax.jit(simulate_core, static_argnames=("config", "policy_names"))


def simulate(
    policy: str,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig = SimConfig(),
    workflow: Workflow | None = None,
) -> SimTrace:
    """Run one registered policy over an (S, N) arrival matrix, optionally
    routing served requests through a ``Workflow`` topology."""
    fleet.validate()
    if workflow is not None:
        check_workflow(workflow, fleet.num_agents)
    return _simulate_jit(
        jnp.asarray(alloc.policy_id(policy)), arrivals, fleet, config,
        alloc.policy_names(), workflow,
    )


# Order of the metric vector returned by trace_metrics (and of the metric
# axis in sweep grids).
METRIC_NAMES = (
    "avg_latency",
    "latency_std",
    "total_throughput",
    "gpu_utilization",
    "mean_queue",
    "littles_law_latency",
    "sink_throughput",
    "critical_path_latency",
)


def critical_path_latency(
    per_agent_latency: jnp.ndarray,
    workflow: Workflow | None,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    """Longest source→sink chain of per-stage latencies through the DAG.

    ``cp_i = lat_i + max over successors cp_j``, iterated N times (a DAG
    over N agents has depth < N), then maximized over source agents.  With
    no workflow every agent is its own one-stage path, so this reduces to
    the max per-agent latency over active agents.
    """
    if workflow is None:
        return (per_agent_latency * mask).max()
    adj = (workflow.route > 0).astype(per_agent_latency.dtype)  # (N, N)
    n = per_agent_latency.shape[-1]

    def body(_, cp):
        return per_agent_latency + (adj * cp[None, :]).max(axis=-1)

    cp = jax.lax.fori_loop(0, n, body, per_agent_latency)
    return (cp * workflow.source * mask).max()


def trace_metrics(
    trace: SimTrace,
    active: jnp.ndarray | None = None,
    workflow: Workflow | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Table II + workflow reductions for one trace, jit/vmap-safe.

    Returns (metric vector in METRIC_NAMES order, per-agent mean latency,
    per-agent mean throughput, per-agent mean queue — the per-stage backlog
    of a workflow pipeline).  The single definition behind both
    ``summarize`` and the sweep grids.

    ``active`` is the fleet's validity mask: per-agent means/stds weight by
    it, so padded slots (latency 0, throughput 0) never dilute the metrics.
    With the default all-ones mask this is exactly the unweighted reduction.
    ``workflow`` feeds the end-to-end metrics: ``sink_throughput`` counts
    requests *exiting* the workflow (served = sink throughput when nothing
    is routed) and ``critical_path_latency`` chains per-stage latencies
    along the routing DAG.
    """
    m = jnp.ones(trace.latency.shape[-1]) if active is None else active
    n_active = jnp.maximum(m.sum(), 1.0)
    mmean = lambda x: (x * m).sum() / n_active  # masked mean over agents
    per_lat = trace.latency.mean(axis=0)
    per_tput = trace.served.mean(axis=0)
    per_queue = trace.queue.mean(axis=0)
    completed = trace.completed  # == served when nothing is routed
    # Unclipped long-run latency: mean backlog over long-run service rate.
    longrun_rate = jnp.maximum(per_tput, _EPS)
    littles = mmean(per_queue / longrun_rate)
    lat_mean = mmean(per_lat)
    lat_std = jnp.sqrt(mmean((per_lat - lat_mean) ** 2))
    vec = jnp.stack([
        lat_mean,
        lat_std,
        per_tput.sum(),
        trace.allocation.sum(axis=1).mean(),
        mmean(per_queue),
        littles,
        (completed.mean(axis=0) * m).sum(),
        critical_path_latency(per_lat, workflow, m),
    ])
    return vec, per_lat, per_tput, per_queue


def summarize(
    policy: str,
    trace: SimTrace,
    config: SimConfig = SimConfig(),
    active: jnp.ndarray | None = None,
    workflow: Workflow | None = None,
) -> SimSummary:
    """Table II metrics from a trace (``active`` masks padded agents)."""
    vec, per_agent_lat, per_agent_tput, per_agent_queue = trace_metrics(
        trace, active, workflow
    )
    duration_s = trace.served.shape[0]
    cost = config.num_gpus * duration_s / 3600.0 * config.price_per_hour
    m = dict(zip(METRIC_NAMES, (float(x) for x in vec)))
    return SimSummary.from_metrics(
        policy, m, per_agent_lat, per_agent_tput, per_agent_queue, cost
    )


def run_policy(
    policy: str,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig = SimConfig(),
    workflow: Workflow | None = None,
) -> SimSummary:
    return summarize(
        policy,
        simulate(policy, arrivals, fleet, config, workflow),
        config,
        fleet.active,
        workflow,
    )
