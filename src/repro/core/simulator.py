"""Discrete-time fleet simulator (paper §IV-B), pure JAX ``lax.scan``.

Semantics reconstructed from the paper (DESIGN.md §6):

* one-second timesteps; requests arrive, the allocator distributes the GPU,
  agents serve ``min(g_i·T_i, queue_i + arrivals_i)`` (throughput scales
  proportionally with allocation), leftovers carry over FIFO;
* per-step latency estimate is the Little's-law drain time of the post-step
  queue at the *current* service rate, clipped at ``latency_cap`` seconds —
  a starved agent (g=0, e.g. off-turn under round-robin) reports the cap.
  This clipping is what produces the paper's round-robin figure of
  756.1 s ≈ 0.75·1000 + on-turn drain; we reproduce it faithfully and also
  expose the unclipped long-run latency (``littles_law_latency``);
* cost is billed on **warm-instance-seconds** (``capacity.billing_cost``):
  with the default always-on pool this reduces to the provisioned-device
  cost of Table II (duration · price/hour, identical across policies), but
  under an elastic capacity policy it is genuinely policy-dependent.

**Serverless capacity** (``core/capacity.py``) makes the budget itself part
of the dynamics: with a ``CapacityConfig`` the scan carries a warm-pool
autoscaler state and the allocator's budget becomes the traced trajectory
``g_total(t) = warm(t)`` — discrete instances, cold-start delay lines,
scale-to-zero keep-alive windows, an instance ceiling at
``SimConfig.num_gpus``.  With ``capacity=None`` the budget stays the static
python float ``config.g_total`` — exactly the pre-capacity program — and
``fixed`` capacity with zero cold start reproduces it bit-for-bit
(regression-tested per policy in tests/test_capacity.py).

**Workflow routing** (``core/routing.py``) makes the multi-agent dataflow
itself part of the dynamics: each step's *served* requests at agent i are
routed into downstream queues for step t+1
(``arrivals_endogenous = (served * fan_out) @ route``), exogenous
generators feed only ``workflow.source`` agents, and the row deficit of the
routing matrix exits the workflow as completed end-to-end requests
(``SimTrace.completed``).  Policies observe the *total* intake — exogenous
plus endogenous — so queue-pressure and rate-driven allocators both react
to collaborative cascades.  With no workflow (or ``routing.independent``)
the endogenous term is identically zero and trajectories are bit-for-bit
what they were before routing existed.

The whole run is one ``lax.scan``; policies are selected with ``lax.switch``
built from the allocator's policy registry, and ``Fleet`` / ``Workflow``
are registered pytrees, so a (fleets × policies × workloads) or
(workflows × policies × workloads) sweep is plain nested ``vmap`` — see
``core/sweep.py`` for the grid runners.  Padded fleets are first-class:
arrivals are gated by ``fleet.active`` and every metric reduction is
mask-weighted, so a padded fleet reports the same numbers as its unpadded
original.

**Streaming mode** (``simulate_stream_core``) is the sweep grids' hot
path: the whole policy axis runs inside one scan (each registered policy
dispatched exactly once per step via ``allocator.policy_stack``) and the
METRIC_NAMES reductions accumulate in the carry (``MetricAccum``), so no
(S, N) trajectory is ever materialized — ``trace_metrics`` and the
streaming carry share one finalizer (``finalize_metrics``), keeping
exactly one metric definition.  ``simulate``/``simulate_core`` remain the
single-run, trace-producing API.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import allocator as alloc
from repro.core import capacity as cap_mod
from repro.core import failures as fail_mod
from repro.core.agents import Fleet, T4_PRICE_PER_HOUR
from repro.core.capacity import CapacityConfig, billing_cost
from repro.core.failures import FailureSpec
from repro.core.routing import Workflow, check_workflow

_EPS = 1e-9

# Env default for the streaming kernel's time-block size (see
# ``resolve_block_size`` / ``simulate_stream_core(block_size=)``).
BLOCK_ENV = "REPRO_SWEEP_BLOCK"


def resolve_block_size(block_size: int | None = None) -> int:
    """Resolve the streaming time-block size B to a concrete python int.

    Explicit ``block_size`` wins; ``None`` falls back to the
    ``REPRO_SWEEP_BLOCK`` env var, then to 1 (the classic single-level
    scan).  B is a trace constant — it sizes the inner unrolled scan — so
    it must be resolved *before* jit, never traced.
    """
    if block_size is None:
        raw = os.environ.get(BLOCK_ENV, "").strip()
        block_size = int(raw) if raw else 1
    b = int(block_size)
    if b < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    return b


def __getattr__(attr: str):
    # Back-compat alias; the registry is authoritative (alloc.policy_id).
    if attr == "POLICY_IDS":
        return {name: i for i, name in enumerate(alloc.policy_names())}
    raise AttributeError(f"module {__name__!r} has no attribute {attr!r}")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static simulation knobs (hashable; a jit static argument).

    ``g_total`` is the provisioned budget: the allocator's constant budget
    when no capacity layer runs, and the warm pool's t=0 baseline when one
    does.  ``num_gpus`` is the **warm-pool instance ceiling** — the most
    instances any capacity policy may keep warm or pending (it is *not* a
    second copy of the budget; configs with ``g_total > num_gpus`` are
    rejected, since the static budget could never be provisioned under its
    own ceiling).  ``price_per_hour`` bills warm-instance-seconds via
    ``capacity.billing_cost``.
    """

    num_steps: int = 100
    g_total: float = 1.0
    latency_cap: float = 1000.0
    price_per_hour: float = T4_PRICE_PER_HOUR
    num_gpus: float = 1.0
    ema_alpha: float = 0.3

    def __post_init__(self):
        cap_mod.check_budget_ceiling(self.g_total, self.num_gpus)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimTrace:
    """Per-step, per-agent trajectories: everything Fig. 2 plots.

    ``arrivals`` records the *exogenous* input (gated by the workflow's
    source flags and the fleet's active mask); ``completed`` the requests
    that exited the workflow at each agent (= served, when no workflow
    routes traffic).  The difference between served and completed is the
    endogenous traffic forwarded downstream.

    ``warm`` is the allocator's per-step budget ``g_total(t)`` — the warm
    instance count under a capacity policy, the constant ``config.g_total``
    without one; ``pending`` counts instances still in their cold start.
    """

    allocation: jnp.ndarray  # (S, N) g_i(t)
    served: jnp.ndarray      # (S, N) requests served in step t
    queue: jnp.ndarray       # (S, N) backlog after step t
    latency: jnp.ndarray     # (S, N) clipped drain-time estimate
    arrivals: jnp.ndarray    # (S, N) exogenous arrivals (source-gated)
    completed: jnp.ndarray = None  # (S, N) requests exiting the workflow
    warm: jnp.ndarray = None       # (S,) warm instances = g_total(t)
    pending: jnp.ndarray = None    # (S,) instances mid cold start
    # Failure/robustness trajectories (zeros when nothing fails).
    misrouted: jnp.ndarray = None  # (S, N) mass routed into inactive slots
    dropped: jnp.ndarray = None    # (S, N) deadline drops (budget exhausted)
    retried: jnp.ndarray = None    # (S, N) deadline-expired mass re-queued
    expired: jnp.ndarray = None    # (S, N) SLO-violating mass (pre-retry)
    recovery: jnp.ndarray = None   # (S,) post-outage backlog-drain indicator

    def __post_init__(self):
        if self.completed is None:
            self.completed = self.served
        if self.warm is None:
            self.warm = jnp.ones(self.served.shape[:-1], jnp.float32)
        if self.pending is None:
            self.pending = jnp.zeros(self.served.shape[:-1], jnp.float32)
        for f in ("misrouted", "dropped", "retried", "expired"):
            if getattr(self, f) is None:
                setattr(self, f, jnp.zeros_like(self.served))
        if self.recovery is None:
            self.recovery = jnp.zeros(self.served.shape[:-1], jnp.float32)

    def tree_flatten(self):
        return (self.allocation, self.served, self.queue, self.latency,
                self.arrivals, self.completed, self.warm, self.pending,
                self.misrouted, self.dropped, self.retried, self.expired,
                self.recovery), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class SimSummary:
    """Table II row for one policy."""

    policy: str
    avg_latency: float
    latency_std: float          # std across agents' mean latencies (Table II)
    per_agent_latency: tuple
    total_throughput: float     # served requests / second
    per_agent_throughput: tuple
    cost: float                 # warm-instance-seconds billed in $
    gpu_utilization: float      # mean Σ g_i
    littles_law_latency: float  # unclipped long-run estimate
    mean_queue: float
    # Workflow (end-to-end) metrics; equal their per-agent analogues when no
    # workflow routes traffic.
    sink_throughput: float = 0.0        # requests exiting the workflow / s
    critical_path_latency: float = 0.0  # longest source→sink latency chain
    per_agent_queue: tuple = ()         # per-stage mean backlog
    # Serverless capacity metrics; under the default always-on pool
    # utilization == gpu_utilization / g_total and the stall time is 0.
    utilization: float = 0.0            # Σ g / warm-instance-seconds
    cold_start_stall_time: float = 0.0  # backlogged seconds with pending pool
    mean_warm_instances: float = 0.0    # mean warm pool size
    # Failure/robustness metrics; all 0 when nothing fails.
    dropped: float = 0.0                # deadline drops / s (budget exhausted)
    retried: float = 0.0                # deadline-expired mass re-queued / s
    slo_violations: float = 0.0         # deadline-expired mass / s (pre-retry)
    recovery_time: float = 0.0          # steps draining post-outage backlog
    misrouted: float = 0.0              # mass lost to inactive route slots / s

    @classmethod
    def from_metrics(
        cls,
        policy: str,
        m: dict,
        per_agent_latency,
        per_agent_throughput,
        per_agent_queue,
    ) -> "SimSummary":
        """The one METRIC_NAMES-dict → summary mapping, shared by
        ``summarize`` and ``SweepResult.summary`` so a new metric cannot be
        threaded through one path and silently default on the other."""
        return cls(
            policy=policy,
            avg_latency=m["avg_latency"],
            latency_std=m["latency_std"],
            per_agent_latency=tuple(float(x) for x in per_agent_latency),
            total_throughput=m["total_throughput"],
            per_agent_throughput=tuple(float(x) for x in per_agent_throughput),
            cost=m["cost"],
            gpu_utilization=m["gpu_utilization"],
            littles_law_latency=m["littles_law_latency"],
            mean_queue=m["mean_queue"],
            sink_throughput=m["sink_throughput"],
            critical_path_latency=m["critical_path_latency"],
            per_agent_queue=tuple(float(x) for x in per_agent_queue),
            utilization=m["utilization"],
            cold_start_stall_time=m["cold_start_stall_time"],
            mean_warm_instances=m["mean_warm_instances"],
            dropped=m["dropped"],
            retried=m["retried"],
            slo_violations=m["slo_violations"],
            recovery_time=m["recovery_time"],
            misrouted=m["misrouted"],
        )


def _routing_terms(
    workflow: Workflow | None, fleet: Fleet, arrivals: jnp.ndarray | None
):
    """Shared scan prep: gate exogenous arrivals, precompute routing terms.

    With ``workflow=None`` the routing terms are ``None`` — the scan body's
    signal to skip the endogenous path entirely (see ``_queue_step``).

    Returns ``(route_eff, exit_frac, gated_arrivals, gate)``: the 0/1
    ``gate`` mask (active, source-restricted under a workflow) is what the
    streaming scan applies per step when arrivals are *synthesized* in the
    body instead of materialized up front (``arrivals=None``).  Gating by
    the fused mask is bit-identical to the old two-multiply chain: 0/1
    masks multiply exactly in any association order.
    """
    if workflow is None:
        gate = fleet.active
        route_eff = exit_frac = None
    else:
        route_eff = workflow.route * workflow.fan_out[..., :, None]  # forwarded copies
        exit_frac = jnp.maximum(1.0 - workflow.route.sum(axis=-1), 0.0)
        gate = fleet.active * workflow.source
    gated = None if arrivals is None else arrivals * gate
    return route_eff, exit_frac, gated, gate


def _queue_step(
    queue: jnp.ndarray,
    lam: jnp.ndarray,
    g: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig,
    route_eff: jnp.ndarray | None,
    exit_frac: jnp.ndarray | None,
):
    """One step of the serving/queueing physics — THE definition, shared by
    the trace scan (``simulate_core``) and the streaming scan
    (``simulate_stream_core``); the state arrays may carry a leading policy
    axis (broadcasting handles both).

    ``route_eff=None`` is the workflow-free fast path: the routing matrix
    would be the N×N zero matrix and ``exit_frac`` identically 1, so the
    ``served @ route`` contraction burns O(N²) multiplies per step producing
    exact zeros.  Skipping it keeps the output bit-for-bit (``served · 1.0
    == served``, and the endogenous term was exactly zero already) — the
    no-op guarantee regression-tested in tests/test_routing.py.
    """
    capacity_rps = g * fleet.base_throughput
    served = jnp.minimum(capacity_rps, queue + lam)
    new_queue = queue + lam - served
    latency = jnp.minimum(
        new_queue / jnp.maximum(capacity_rps, _EPS), config.latency_cap
    )
    if route_eff is None:
        completed = served
        new_endo = jnp.zeros_like(served)
        mis = jnp.zeros_like(served)
    else:
        completed = served * exit_frac  # row deficit exits the workflow
        # Routed mass arrives downstream next step; the active gate keeps
        # padded slots inert even if a route column points at one.  The
        # misrouted mass is dropped, exactly like gated exogenous traffic —
        # but it is *accounted*, so conservation stays checkable.
        fwd = served @ route_eff
        new_endo = fwd * fleet.active
        mis = fwd * (1.0 - fleet.active)
    return served, new_queue, latency, completed, new_endo, mis


def _failure_queue_step(
    queue: jnp.ndarray,
    lam: jnp.ndarray,
    g: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig,
    route_eff: jnp.ndarray | None,
    exit_frac: jnp.ndarray | None,
    failures: FailureSpec,
    phi: jnp.ndarray,
    up: jnp.ndarray,
    retry_q: jnp.ndarray,
):
    """Failure-aware twin of ``_queue_step`` (only compiled when a
    ``FailureSpec`` is passed — the ``failures=None`` program never sees it).

    * **agent outage** (``up`` → 0): the agent's effective capacity is
      zeroed (no reallocation — its share idles), the queue is preserved
      and arrivals keep accumulating across the outage;
    * **revocation** (``phi`` > 0): a ``phi`` fraction of warm capacity is
      yanked mid-step — its in-service work drains back into the queue
      (``served = served_raw · (1-phi)``, so the clawed-back mass stays in
      ``new_queue`` by mass balance);
    * **deadlines**: :func:`repro.core.failures.deadline_step` expires the
      backlog beyond the deadline's worth of effective service, retrying
      (class promotion, bounded by ``retry_budget``) or dropping it.

    Returns ``(served, new_queue, latency, completed, new_endo, mis,
    new_retry_q, dropped, retried, viol)``.  Mass balance per agent:
    ``new_queue = queue + lam - served - dropped``.
    """
    capacity_rps = g * up * fleet.base_throughput
    served_raw = jnp.minimum(capacity_rps, queue + lam)
    served = served_raw * (1.0 - phi)
    q_post = queue + lam - served
    cap_eff = capacity_rps * (1.0 - phi)
    new_queue, new_retry_q, dropped, retried, viol = fail_mod.deadline_step(
        failures, queue, lam, served, q_post, cap_eff, retry_q, eps=_EPS
    )
    # The drop accounting can leave a roundoff residue where the true
    # post-drop queue is exactly zero.  Snap it to an exact zero (and gate
    # the clipped-latency cliff on the same dead band) so the f32 kernel
    # and the f64 oracle cannot land on opposite sides of a queue>0 branch
    # — discrete allocators (throughput_greedy) chase any positive demand,
    # and the 0-vs-latency_cap latency boundary is a 1000x cliff.  The
    # snapped mass is bounded by the dead band per agent-step.
    new_queue = new_queue * (new_queue > 1e-4)
    latency = jnp.minimum(
        new_queue / jnp.maximum(cap_eff, _EPS), config.latency_cap
    ) * (new_queue > 1e-4)
    if route_eff is None:
        completed = served
        new_endo = jnp.zeros_like(served)
        mis = jnp.zeros_like(served)
    else:
        completed = served * exit_frac
        fwd = served @ route_eff
        new_endo = fwd * fleet.active
        mis = fwd * (1.0 - fleet.active)
    return (served, new_queue, latency, completed, new_endo, mis,
            new_retry_q, dropped, retried, viol)


def simulate_core(
    policy_id: jnp.ndarray,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig,
    policy_names: Sequence[str] | None = None,
    workflow: Workflow | None = None,
    capacity: CapacityConfig | None = None,
    failures: FailureSpec | None = None,
) -> SimTrace:
    """Pure scan body — jit/vmap-able over ``policy_id``, ``arrivals``, the
    ``fleet`` pytree, the ``workflow`` pytree and the ``capacity`` pytree
    (any of which may carry a batch axis).

    The EMA carry is seeded with the first observation; the update is skipped
    at t=0 so that observation is not applied twice.  Exogenous arrivals are
    gated by ``fleet.active`` (padding slots never accumulate queue) and by
    ``workflow.source`` (only source agents see outside traffic); each
    step's served requests are fanned into downstream queues for the next
    step via the routing matrix.  With ``workflow=None`` the endogenous
    path contributes exact zeros — trajectories are bit-for-bit identical
    to the pre-routing simulator.

    With a ``capacity`` config the scan also carries the warm-pool state:
    the autoscaler runs *before* the allocation policy each step (cohorts
    warm up, the idle clock ticks, desired count is chosen) and the policy's
    budget is the traced ``warm(t)`` instead of the static ``config.g_total``.
    With ``capacity=None`` the budget stays a python float — the literal
    pre-capacity program — which the ``fixed``/zero-cold-start capacity path
    must reproduce bit-for-bit (tests/test_capacity.py).

    ``failures`` injects revocation / agent-outage / deadline dynamics
    (``core/failures.py``): the chain state rides the carry, the physics
    switch to ``_failure_queue_step``, and the trace grows the
    dropped/retried/expired/misrouted/recovery trajectories.  The
    ``failures=None`` branch is resolved in *python*, so the no-failure
    program is structurally the pre-failure program — bit-for-bit, not
    merely numerically close (tests/test_failures.py).
    """
    names = alloc.policy_names() if policy_names is None else tuple(policy_names)
    n = fleet.num_agents
    route_eff, exit_frac, arrivals, _ = _routing_terms(workflow, fleet, arrivals)
    elastic = capacity is not None
    failing = failures is not None

    def step(carry, inp):
        if failing:
            fstate = carry[-1]
            carry = carry[:-1]
        if elastic:
            queue, lam_ema, endo, cstate = carry
        else:
            queue, lam_ema, endo = carry
        t, lam_exo = inp
        lam = lam_exo + endo            # total intake: exogenous + routed
        lam_ema = jnp.where(
            t > 0, alloc.ema_forecast(lam_ema, lam, config.ema_alpha), lam_ema
        )
        if elastic:
            cstate, g_total_t, pending_t = cap_mod.capacity_step(
                cstate, capacity, t, lam.sum(), lam_ema.sum(), queue.sum(),
                config.g_total, config.num_gpus,
            )
        else:
            g_total_t = config.g_total  # static python float: the pre-capacity program
            pending_t = jnp.zeros((), jnp.float32)
        g = alloc.policy_switch(
            policy_id, t, lam, lam_ema, queue, fleet, g_total_t, names
        )
        if failing:
            u_rev, u_down = fail_mod.failure_uniforms(failures, t, n)
            phi, avail, rev_nxt, down_nxt = fail_mod.advance_failures(
                failures, t, fstate.rev_on, fstate.down, u_rev, u_down
            )
            fail_t = jnp.maximum(
                (phi > 0).astype(jnp.float32),
                (((1.0 - avail) * fleet.active) > 0.5).any().astype(jnp.float32),
            )
            pre_q_tot = (queue * fleet.active).sum(-1)
            onset = fail_t * (1.0 - fstate.fail_prev) * (1.0 - fstate.recovering)
            q_mark = jnp.where(onset > 0, pre_q_tot, fstate.q_mark)
            (served, new_queue, latency, completed, new_endo, mis,
             new_retry_q, dropped, retried, viol) = _failure_queue_step(
                queue, lam, g, fleet, config, route_eff, exit_frac,
                failures, phi, avail, fstate.retry_q,
            )
            # Recovery bookkeeping: once the failure clears, count the steps
            # until the backlog drains back under its pre-outage watermark.
            new_q_tot = (new_queue * fleet.active).sum(-1)
            in_rec = (1.0 - fail_t) * jnp.maximum(fstate.fail_prev,
                                                  fstate.recovering)
            recovering = jnp.where(
                fail_t > 0, fstate.recovering,
                in_rec * (new_q_tot > q_mark).astype(jnp.float32),
            )
            fstate = fail_mod.FailureState(
                rev_on=rev_nxt, down=down_nxt, fail_prev=fail_t,
                recovering=recovering, q_mark=q_mark, retry_q=new_retry_q,
            )
            if elastic:
                # Revoked instances leave the warm pool: the autoscaler must
                # re-provision them through the cold-start pipeline.
                cstate = cap_mod.CapacityState(
                    cstate.warm * (1.0 - phi), cstate.pipeline, cstate.idle_s
                )
        else:
            served, new_queue, latency, completed, new_endo, mis = _queue_step(
                queue, lam, g, fleet, config, route_eff, exit_frac
            )
        warm_t = jnp.asarray(g_total_t, jnp.float32)
        if failing:
            # Billing excludes revoked instance-seconds: the yanked share
            # of the pool is not warm capacity for this step.
            warm_t = warm_t * (1.0 - phi)
        new_carry = (
            (new_queue, lam_ema, new_endo, cstate) if elastic
            else (new_queue, lam_ema, new_endo)
        )
        out = (g, served, new_queue, latency, completed, warm_t, pending_t, mis)
        if failing:
            new_carry = new_carry + (fstate,)
            out = out + (dropped, retried, viol, in_rec)
        return new_carry, out

    num_steps = arrivals.shape[0]
    ts = jnp.arange(num_steps)
    init = (
        jnp.zeros(n, jnp.float32),
        arrivals[0],
        jnp.zeros(n, jnp.float32),
    )
    if elastic:
        init = init + (cap_mod.init_capacity_state(config.g_total),)
    if failing:
        init = init + (fail_mod.init_failure_state(n),)
    _, outs = jax.lax.scan(step, init, (ts, arrivals))
    g, served, queue, latency, completed, warm, pending, mis = outs[:8]
    if failing:
        dropped, retried, viol, recovery = outs[8:]
    else:
        dropped = retried = viol = recovery = None
    return SimTrace(g, served, queue, latency, arrivals, completed, warm,
                    pending, mis, dropped, retried, viol, recovery)


# ``Fleet``, ``Workflow`` and ``CapacityConfig`` are registered pytrees
# (names are static aux data), so they pass straight through jit — no
# array/static plumbing.
_simulate_jit = jax.jit(simulate_core, static_argnames=("config", "policy_names"))


def simulate(
    policy: str,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig = SimConfig(),
    workflow: Workflow | None = None,
    capacity: CapacityConfig | None = None,
    failures: FailureSpec | None = None,
) -> SimTrace:
    """Run one registered policy over an (S, N) arrival matrix, optionally
    routing served requests through a ``Workflow`` topology, scaling the
    warm pool with a ``CapacityConfig`` autoscaler, and/or injecting
    failures from a ``FailureSpec`` chaos scenario."""
    fleet.validate()
    if workflow is not None:
        check_workflow(workflow, fleet.num_agents)
    if capacity is not None:
        cap_mod.check_capacity(capacity, config.g_total, config.num_gpus)
    failures = fail_mod.resolve_failures(failures)
    if failures is not None:
        fail_mod.check_failures(failures)
        if failures.batched:
            raise ValueError(
                "simulate() takes a single FailureSpec; batched (stacked) "
                "specs only flow through sweep(..., failures=[...])"
            )
    return _simulate_jit(
        jnp.asarray(alloc.policy_id(policy)), arrivals, fleet, config,
        alloc.policy_names(), workflow, capacity, failures,
    )


def simulate_stream_core(
    arrivals: jnp.ndarray | None,
    fleet: Fleet,
    config: SimConfig,
    policy_names: Sequence[str] | None = None,
    workflow: Workflow | None = None,
    capacity: CapacityConfig | None = None,
    workload_spec=None,
    num_policy_blocks: int = 1,
    policy_block: jnp.ndarray | None = None,
    block_size: int | None = None,
    gen_name: str | None = None,
    failures: FailureSpec | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused streaming scan: every named policy's trajectory AND its metric
    reductions in ONE pass, materializing no per-step traces.

    The sweep grids' hot path (``core/sweep.py``).  Two structural changes
    versus vmapping ``simulate_core`` over a policy axis:

    * **O(P) dispatch** — under ``vmap`` the per-step ``lax.switch`` lowers
      to evaluate-all-branches-and-select, so each of P policy rows computes
      all P policies (P² allocator evaluations per step).  Here the policy
      axis lives *inside* the scan as a (P, N) state stack and
      ``alloc.policy_stack`` dispatches each named policy exactly once per
      step, on its own row.
    * **O(1)-in-time memory** — the carry folds each step's outputs straight
      into a ``MetricAccum`` (running METRIC_NAMES sums); nothing of shape
      (S, ·) is ever materialized, so peak memory per cell is O(P · N)
      however long the horizon.

    **In-scan synthesis** closes the input side too: pass a
    ``workload.WorkloadSpec`` (and ``arrivals=None``) and step t's arrival
    row is computed *inside* the scan body from the O(N) parameter row —
    ``workload_step`` with a ``fold_in(key, t)`` counter-based draw, MMPP
    state riding the carry — so nothing of shape (S, ·) exists on either
    end of the scan.  Synthesized runs are bit-for-bit identical to running
    the same spec through ``workload.materialize`` and passing the tensor:
    the materializer scans the very same registered step functions.

    **Policy-axis sharding** (``num_policy_blocks`` > 1): the named policy
    list is cut into equal contiguous blocks and this invocation computes
    only block ``policy_block`` (a traced index — under ``shard_map`` it is
    ``lax.axis_index("policy")``).  Each block still gets the O(P) unrolled
    dispatch via ``allocator.policy_stack_blocks``; state/metric rows shrink
    to P/blocks per device.

    **Time blocking** (``block_size`` > 1, env ``REPRO_SWEEP_BLOCK``): the
    scan becomes two-level — an outer ``lax.scan`` over ⌈S/B⌉ blocks whose
    body synthesizes a whole (B, N) arrival block in one
    ``workload.step_block`` call (one generator dispatch per block instead
    of per step, and one *batched* RNG draw per block for the expensive
    samplers) and runs B physics/dispatch steps through an inner rolled
    scan.  Unrolling that inner scan was measured a net loss on XLA CPU —
    ~1.7× slower execution and far longer compiles than the rolled loop —
    so blocking's payoff is entirely in the amortized synthesis, not in
    loop unrolling.  A non-divisible horizon is handled by a masked tail
    block: steps with ``t >= S`` keep the previous carry element-wise, so
    they change nothing.  ``block_size=1`` routes to the original
    single-level scan verbatim, and every block size yields bit-identical
    metrics (tests/test_streaming.py) — B trades compile time for step
    throughput, never results.  Peak memory per cell grows to O(B·N);
    both ends of the scan stay horizon-free.

    ``gen_name`` statically names the spec's generator when the caller
    knows it at trace time (the grouped-dispatch sweep path,
    ``sweep.synth_gen_groups``): synthesis then calls that generator
    directly instead of through ``lax.switch``, whose vmapped
    evaluate-all-branches lowering makes every scenario column pay every
    registered generator.  Results are bit-identical either way.

    Physics (``_queue_step``), EMA seeding, the autoscaler
    (``capacity_step``, vmapped over the policy rows — each policy's queue
    trajectory drives its own warm pool) and the metric finalizer
    (``finalize_metrics``) are all shared with the trace-based path, which
    remains the parity oracle: streaming metrics match
    ``trace_metrics(simulate_core(...))`` within float tolerance
    (tests/test_streaming.py).

    Returns ``(metrics (P, M), per-agent latency (P, N), per-agent
    throughput (P, N), per-agent queue (P, N))`` with P = len(policy_names)
    in name order (P/blocks rows of the current block when blocked) and
    M = len(METRIC_NAMES).
    """
    from repro.core import workload as workload_mod

    names = alloc.policy_names() if policy_names is None else tuple(policy_names)
    if (arrivals is None) == (workload_spec is None):
        raise ValueError("pass exactly one of arrivals= / workload_spec=")
    synth = workload_spec is not None
    blocks = int(num_policy_blocks)
    if blocks > 1:
        if len(names) % blocks:
            raise ValueError(
                f"{len(names)} policies do not split into {blocks} equal blocks"
            )
        if policy_block is None:
            raise ValueError("num_policy_blocks > 1 requires policy_block")
    p, n = len(names) // blocks, fleet.num_agents
    route_eff, exit_frac, arrivals, gate = _routing_terms(
        workflow, fleet, arrivals
    )
    elastic = capacity is not None
    failing = failures is not None
    if elastic:
        # vmap over the policy rows only; the config itself is shared.  The
        # inner ``lax.switch`` keeps its unbatched index, so no branch blowup.
        cap_step = jax.vmap(
            cap_mod.capacity_step, in_axes=(0, None, None, 0, 0, 0, None, None)
        )

    def dispatch(t, lam, lam_ema, queue, g_total_t):
        if blocks > 1:
            return alloc.policy_stack_blocks(
                t, lam, lam_ema, queue, fleet, g_total_t, names,
                blocks, policy_block,
            )
        return alloc.policy_stack(t, lam, lam_ema, queue, fleet, g_total_t, names)

    def step_body(carry, t, lam_exo):
        # One streaming step on the workload-state-free carry:
        # (queue, lam_ema, endo, acc[, cstate][, fstate]).
        queue, lam_ema, endo, acc = carry[:4]
        rest = carry[4:]
        if failing:
            fstate = rest[-1]
            rest = rest[:-1]
        lam = lam_exo + endo            # (P, N) total intake per policy row
        lam_ema = jnp.where(
            t > 0, alloc.ema_forecast(lam_ema, lam, config.ema_alpha), lam_ema
        )
        if elastic:
            cstate, g_total_t, pending_t = cap_step(
                rest[-1], capacity, t, lam.sum(axis=-1), lam_ema.sum(axis=-1),
                queue.sum(axis=-1), config.g_total, config.num_gpus,
            )
            rest = rest[:-1] + (cstate,)
        else:
            g_total_t = config.g_total  # static python float: the pre-capacity program
            pending_t = jnp.zeros((p,), jnp.float32)
        g = dispatch(t, lam, lam_ema, queue, g_total_t)
        if failing:
            # The chains are exogenous — one draw shared by every policy
            # row; only the per-policy bookkeeping carries a (P,) axis.
            u_rev, u_down = fail_mod.failure_uniforms(failures, t, n)
            phi, avail, rev_nxt, down_nxt = fail_mod.advance_failures(
                failures, t, fstate.rev_on, fstate.down, u_rev, u_down
            )
            fail_t = jnp.maximum(
                (phi > 0).astype(jnp.float32),
                (((1.0 - avail) * fleet.active) > 0.5).any().astype(jnp.float32),
            )
            pre_q_tot = (queue * fleet.active).sum(-1)          # (P,)
            onset = fail_t * (1.0 - fstate.fail_prev) * (1.0 - fstate.recovering)
            q_mark = jnp.where(onset > 0, pre_q_tot, fstate.q_mark)
            (served, new_queue, latency, completed, new_endo, mis,
             new_retry_q, dropped, retried, viol) = _failure_queue_step(
                queue, lam, g, fleet, config, route_eff, exit_frac,
                failures, phi, avail, fstate.retry_q,
            )
            new_q_tot = (new_queue * fleet.active).sum(-1)
            in_rec = (1.0 - fail_t) * jnp.maximum(fstate.fail_prev,
                                                  fstate.recovering)
            recovering = jnp.where(
                fail_t > 0, fstate.recovering,
                in_rec * (new_q_tot > q_mark).astype(jnp.float32),
            )
            fstate = fail_mod.FailureState(
                rev_on=rev_nxt, down=down_nxt, fail_prev=fail_t,
                recovering=recovering, q_mark=q_mark, retry_q=new_retry_q,
            )
            if elastic:
                cstate = cap_mod.CapacityState(
                    cstate.warm * (1.0 - phi), cstate.pipeline, cstate.idle_s
                )
                rest = rest[:-1] + (cstate,)
        else:
            served, new_queue, latency, completed, new_endo, mis = _queue_step(
                queue, lam, g, fleet, config, route_eff, exit_frac
            )
            dropped = retried = viol = in_rec = None
        warm_t = jnp.broadcast_to(jnp.asarray(g_total_t, jnp.float32), (p,))
        if failing:
            # Billing excludes revoked instance-seconds (as in simulate_core).
            warm_t = warm_t * (1.0 - phi)
        acc = accumulate_metrics(
            acc, fleet.active, g, served, new_queue, latency, completed,
            warm_t, pending_t, misrouted=mis, dropped=dropped,
            retried=retried, viol=viol, recovery=in_rec,
        )
        out = (new_queue, lam_ema, new_endo, acc) + rest
        if failing:
            out = out + (fstate,)
        return out

    def step(carry, inp):
        # Single-level (block_size=1) scan body: per-step synthesis inline.
        if synth:
            t = inp
            lam_row, wstate = workload_mod.workload_step(
                workload_spec, carry[4], t, gen=gen_name
            )
            out = step_body(carry[:4] + carry[5:], t, lam_row * gate)
            return out[:4] + (wstate,) + out[4:], None
        t, lam_exo = inp
        return step_body(carry, t, lam_exo), None

    if synth:
        num_steps = workload_spec.num_steps
        wstate0 = workload_mod.workload_init(workload_spec, gen=gen_name)
        # EMA seed = the very row the scan body will synthesize at t=0
        # (same step function, same fold — bit-identical to arrivals[0]
        # of the materialized tensor, gated the same way).
        lam0 = (
            workload_mod.workload_step(
                workload_spec, wstate0, jnp.asarray(0, jnp.int32), gen=gen_name
            )[0]
            * gate
        )
    else:
        num_steps = arrivals.shape[0]
        lam0 = arrivals[0]
    ts = jnp.arange(num_steps)
    init = (
        jnp.zeros((p, n), jnp.float32),
        jnp.broadcast_to(lam0, (p, n)),  # EMA seed, as in simulate_core
        jnp.zeros((p, n), jnp.float32),
        init_metric_accum(n, (p,)),
    )
    if synth:
        init = init + (wstate0,)
    if elastic:
        init = init + (jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (p,) + x.shape),
            cap_mod.init_capacity_state(config.g_total),
        ),)
    if failing:
        # fstate rides LAST in the carry: the chains (rev_on/down/fail_prev)
        # are shared across policy rows, the bookkeeping is per-policy.
        init = init + (fail_mod.init_failure_state(n, (p,)),)
    bsz = resolve_block_size(block_size)
    if bsz == 1:
        carry, _ = jax.lax.scan(step, init, ts if synth else (ts, arrivals))
    else:
        # Two-level blocked scan: the outer scan walks the ⌊S/B⌋ *full*
        # blocks with a mask-free inner scan (the hot path); a
        # non-divisible horizon finishes in one masked tail block below.
        # Both inner scans stay ROLLED: unrolling the full physics body was
        # measured a straight loss on XLA CPU (~1.7× slower at B=128, with
        # far longer compiles — and the tail's per-step where-gate builds
        # select chains the simplifier degenerates on when unrolled).  The
        # block's payoff is the batched per-block synthesis in
        # workload.step_block, not loop unrolling.
        full = num_steps // bsz
        rem = num_steps - full * bsz
        unroll = 1

        def inner_step(carry, inp):
            t, lam_exo = inp
            return step_body(carry, t, lam_exo), None

        def tail_step(carry, inp):
            # Masked tail block: steps past the horizon keep the old carry
            # element-wise (where(True, new, old) == new exactly, so valid
            # steps are untouched by the gate).
            t, lam_exo = inp
            new_carry = step_body(carry, t, lam_exo)
            valid = t < num_steps
            return jax.tree_util.tree_map(
                lambda new, old: jnp.where(valid, new, old), new_carry, carry
            ), None

        def split_wstate(carry):
            # (queue, ema, endo, acc[, wstate][, cstate]) -> workload-free
            # carry for the inner scans + the wstate to thread at block level.
            if synth:
                return carry[:4] + carry[5:], carry[4]
            return carry, None

        def join_wstate(inner, wstate):
            if synth:
                return inner[:4] + (wstate,) + inner[4:]
            return inner

        def run_block(carry, ts_blk, lam_blk, scan_step, unroll):
            inner, wstate = split_wstate(carry)
            if synth:
                lam_blk, wstate = workload_mod.step_block(
                    workload_spec, wstate, ts_blk, gen=gen_name
                )
                lam_blk = lam_blk * gate
            inner, _ = jax.lax.scan(
                scan_step, inner, (ts_blk, lam_blk), unroll=unroll
            )
            return join_wstate(inner, wstate)

        if synth:
            arr_blocks = None
            xs = jnp.arange(full, dtype=ts.dtype) * bsz  # block start t0
        else:
            arr_blocks = arrivals[: full * bsz].reshape(
                (full, bsz) + arrivals.shape[1:]
            )
            xs = (
                jnp.arange(full * bsz, dtype=ts.dtype).reshape(full, bsz),
                arr_blocks,
            )

        def block_step(carry, inp):
            if synth:
                ts_blk = inp + jnp.arange(bsz, dtype=inp.dtype)
                lam_blk = None
            else:
                ts_blk, lam_blk = inp
            return run_block(carry, ts_blk, lam_blk, inner_step, unroll), None

        carry = init
        if full:
            carry, _ = jax.lax.scan(block_step, carry, xs)
        if rem:
            ts_tail = full * bsz + jnp.arange(bsz, dtype=ts.dtype)
            if synth:
                lam_tail = None  # synthesized inside run_block
            else:
                pad = bsz - rem
                lam_tail = jnp.concatenate(
                    [arrivals[full * bsz:],
                     jnp.zeros((pad,) + arrivals.shape[1:], arrivals.dtype)]
                )
            carry = run_block(carry, ts_tail, lam_tail, tail_step, 1)
    acc = carry[3]
    return jax.vmap(
        lambda a: finalize_metrics(
            a, num_steps, fleet.active, workflow, config=config
        )
    )(acc)


# Order of the metric vector returned by trace_metrics (and of the metric
# axis in sweep grids).  Capacity metrics (cost included — it is now
# policy-dependent) live at the end so index-based consumers of the original
# eight keep working.
METRIC_NAMES = (
    "avg_latency",
    "latency_std",
    "total_throughput",
    "gpu_utilization",
    "mean_queue",
    "littles_law_latency",
    "sink_throughput",
    "critical_path_latency",
    "cost",
    "utilization",
    "cold_start_stall_time",
    "mean_warm_instances",
    # Failure/robustness metrics (PR 10) — appended at the end so
    # index-based consumers of the original twelve keep working.
    "dropped",
    "retried",
    "slo_violations",
    "recovery_time",
    "misrouted",
)


def critical_path_latency(
    per_agent_latency: jnp.ndarray,
    workflow: Workflow | None,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    """Longest source→sink chain of per-stage latencies through the DAG.

    ``cp_i = lat_i + max over successors cp_j``, iterated N times (a DAG
    over N agents has depth < N), then maximized over source agents.  With
    no workflow every agent is its own one-stage path, so this reduces to
    the max per-agent latency over active agents.
    """
    if workflow is None:
        return (per_agent_latency * mask).max()
    adj = (workflow.route > 0).astype(per_agent_latency.dtype)  # (N, N)
    n = per_agent_latency.shape[-1]

    def body(_, cp):
        return per_agent_latency + (adj * cp[None, :]).max(axis=-1)

    cp = jax.lax.fori_loop(0, n, body, per_agent_latency)
    return (cp * workflow.source * mask).max()


class MetricAccum(NamedTuple):
    """Running METRIC_NAMES reductions — the streaming scan's metric carry.

    Everything ``trace_metrics`` needs, as O(N) running sums instead of
    (S, N) trajectories: peak memory per cell is independent of the horizon.
    Leaves may carry a leading policy axis (the streaming kernel accumulates
    all P policies at once).
    """

    lat_sum: jnp.ndarray        # (..., N) Σ_t latency
    served_sum: jnp.ndarray     # (..., N) Σ_t served
    queue_sum: jnp.ndarray      # (..., N) Σ_t queue
    completed_sum: jnp.ndarray  # (..., N) Σ_t completed
    alloc_sum: jnp.ndarray      # (...,)   Σ_t Σ_i g_i
    warm_sum: jnp.ndarray       # (...,)   Σ_t warm(t) — warm-instance-seconds
    stall_steps: jnp.ndarray    # (...,)   steps with pending > 0 and backlog
    dropped_sum: jnp.ndarray    # (...,)   Σ_t Σ_i deadline drops
    retried_sum: jnp.ndarray    # (...,)   Σ_t Σ_i re-queued expired mass
    viol_sum: jnp.ndarray       # (...,)   Σ_t Σ_i deadline-expired mass
    misrouted_sum: jnp.ndarray  # (...,)   Σ_t Σ_i mass lost to inactive slots
    recovery_steps: jnp.ndarray # (...,)   steps draining post-outage backlog


def init_metric_accum(num_agents: int, batch_shape: tuple = ()) -> MetricAccum:
    """Zero accumulator for ``batch_shape`` cells of ``num_agents`` agents."""
    agent = jnp.zeros(batch_shape + (num_agents,), jnp.float32)
    scalar = jnp.zeros(batch_shape, jnp.float32)
    return MetricAccum(agent, agent, agent, agent, scalar, scalar, scalar,
                       scalar, scalar, scalar, scalar, scalar)


def accumulate_metrics(
    acc: MetricAccum,
    mask: jnp.ndarray,
    g: jnp.ndarray,
    served: jnp.ndarray,
    queue: jnp.ndarray,
    latency: jnp.ndarray,
    completed: jnp.ndarray,
    warm: jnp.ndarray,
    pending: jnp.ndarray,
    misrouted: jnp.ndarray | None = None,
    dropped: jnp.ndarray | None = None,
    retried: jnp.ndarray | None = None,
    viol: jnp.ndarray | None = None,
    recovery: jnp.ndarray | None = None,
) -> MetricAccum:
    """Fold one step's outputs into the running sums (O(N) work/memory).

    The failure-side inputs default to ``None`` — contributing nothing —
    so the no-failure program folds exactly the same sums as before."""
    backlogged = (queue * mask).sum(axis=-1) > 0
    msum = lambda x: 0.0 if x is None else (x * mask).sum(axis=-1)
    return MetricAccum(
        lat_sum=acc.lat_sum + latency,
        served_sum=acc.served_sum + served,
        queue_sum=acc.queue_sum + queue,
        completed_sum=acc.completed_sum + completed,
        alloc_sum=acc.alloc_sum + g.sum(axis=-1),
        warm_sum=acc.warm_sum + warm,
        stall_steps=acc.stall_steps
        + ((pending > 0) & backlogged).astype(jnp.float32),
        dropped_sum=acc.dropped_sum + msum(dropped),
        retried_sum=acc.retried_sum + msum(retried),
        viol_sum=acc.viol_sum + msum(viol),
        misrouted_sum=acc.misrouted_sum + msum(misrouted),
        recovery_steps=acc.recovery_steps
        + (0.0 if recovery is None else recovery),
    )


def finalize_metrics(
    acc: MetricAccum,
    num_steps: int,
    active: jnp.ndarray | None = None,
    workflow: Workflow | None = None,
    *,
    config: SimConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """METRIC_NAMES reductions from the running sums — THE metric
    definition (unbatched; ``vmap`` it over a policy axis).

    ``trace_metrics`` feeds it sums over a materialized trace, the
    streaming scan feeds it the accumulated carry — either way there is
    exactly one formula per metric, so the two modes cannot drift.

    Returns (metric vector in METRIC_NAMES order, per-agent mean latency,
    per-agent mean throughput, per-agent mean queue).
    """
    m = jnp.ones(acc.lat_sum.shape[-1]) if active is None else active
    n_active = jnp.maximum(m.sum(), 1.0)
    mmean = lambda x: (x * m).sum() / n_active  # masked mean over agents
    per_lat = acc.lat_sum / num_steps
    per_tput = acc.served_sum / num_steps
    per_queue = acc.queue_sum / num_steps
    # Unclipped long-run latency: mean backlog over long-run service rate.
    longrun_rate = jnp.maximum(per_tput, _EPS)
    littles = mmean(per_queue / longrun_rate)
    lat_mean = mmean(per_lat)
    lat_std = jnp.sqrt(mmean((per_lat - lat_mean) ** 2))
    vec = jnp.stack([
        lat_mean,
        lat_std,
        per_tput.sum(),
        acc.alloc_sum / num_steps,
        mmean(per_queue),
        littles,
        (acc.completed_sum / num_steps * m).sum(),
        critical_path_latency(per_lat, workflow, m),
        billing_cost(acc.warm_sum, config.price_per_hour),
        acc.alloc_sum / jnp.maximum(acc.warm_sum, _EPS),
        acc.stall_steps,
        acc.warm_sum / num_steps,
        acc.dropped_sum / num_steps,
        acc.retried_sum / num_steps,
        acc.viol_sum / num_steps,
        acc.recovery_steps,
        acc.misrouted_sum / num_steps,
    ])
    return vec, per_lat, per_tput, per_queue


def trace_metrics(
    trace: SimTrace,
    active: jnp.ndarray | None = None,
    workflow: Workflow | None = None,
    *,
    config: SimConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Table II + workflow + capacity reductions for one trace, jit/vmap-safe.

    Reduces the trace to a ``MetricAccum`` and finalizes — a thin wrapper
    over ``finalize_metrics``, the same finalizer the streaming scan uses,
    so trace mode and streaming mode share one metric definition.  Returns
    (metric vector in METRIC_NAMES order, per-agent mean latency, per-agent
    mean throughput, per-agent mean queue — the per-stage backlog of a
    workflow pipeline).  The single definition behind both ``summarize``
    and the sweep grids.

    ``active`` is the fleet's validity mask: per-agent means/stds weight by
    it, so padded slots (latency 0, throughput 0) never dilute the metrics.
    With the default all-ones mask this is exactly the unweighted reduction.
    ``workflow`` feeds the end-to-end metrics: ``sink_throughput`` counts
    requests *exiting* the workflow (served = sink throughput when nothing
    is routed) and ``critical_path_latency`` chains per-stage latencies
    along the routing DAG.  ``config`` prices the capacity metrics and is
    deliberately required — it must be the config the trace was produced
    under, or the cost column is silently priced wrong: ``cost`` bills the
    trace's warm-instance-seconds, ``utilization`` is the allocated
    fraction of the warm pool, and ``cold_start_stall_time`` counts the
    seconds the fleet sat backlogged while instances were still cold — the
    serverless tax no provisioned-cost model can see.
    """
    m = jnp.ones(trace.latency.shape[-1]) if active is None else active
    backlogged = (trace.queue * m).sum(axis=-1) > 0
    msum = lambda x: (x * m).sum(axis=-1).sum(axis=-1)
    acc = MetricAccum(
        lat_sum=trace.latency.sum(axis=0),
        served_sum=trace.served.sum(axis=0),
        queue_sum=trace.queue.sum(axis=0),
        completed_sum=trace.completed.sum(axis=0),
        alloc_sum=trace.allocation.sum(axis=-1).sum(axis=-1),
        warm_sum=trace.warm.sum(axis=0),  # 1 s steps: Σ_t warm(t) · 1 s
        stall_steps=((trace.pending > 0) & backlogged).sum().astype(jnp.float32),
        dropped_sum=msum(trace.dropped),
        retried_sum=msum(trace.retried),
        viol_sum=msum(trace.expired),
        misrouted_sum=msum(trace.misrouted),
        recovery_steps=trace.recovery.sum(axis=0),
    )
    return finalize_metrics(
        acc, trace.latency.shape[0], active, workflow, config=config
    )


def summarize(
    policy: str,
    trace: SimTrace,
    config: SimConfig = SimConfig(),
    active: jnp.ndarray | None = None,
    workflow: Workflow | None = None,
) -> SimSummary:
    """Table II metrics from a trace (``active`` masks padded agents)."""
    vec, per_agent_lat, per_agent_tput, per_agent_queue = trace_metrics(
        trace, active, workflow, config=config
    )
    m = dict(zip(METRIC_NAMES, (float(x) for x in vec)))
    return SimSummary.from_metrics(
        policy, m, per_agent_lat, per_agent_tput, per_agent_queue
    )


def run_policy(
    policy: str,
    arrivals: jnp.ndarray,
    fleet: Fleet,
    config: SimConfig = SimConfig(),
    workflow: Workflow | None = None,
    capacity: CapacityConfig | None = None,
    failures: FailureSpec | None = None,
) -> SimSummary:
    return summarize(
        policy,
        simulate(policy, arrivals, fleet, config, workflow, capacity, failures),
        config,
        fleet.active,
        workflow,
    )
