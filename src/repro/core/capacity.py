"""Serverless capacity layer: discrete warm-pool autoscaling with cold starts.

The paper's setting is *serverless* GPU platforms, yet a naive reproduction
models a permanently provisioned device: the allocator's budget ``g_total``
is a constant and cost is ``num_gpus · duration · price`` — identical across
every policy, so the paper's cost-efficiency claims are vacuous.  This module
makes capacity itself dynamic: ``g_total(t)`` becomes the traced output of a
**warm-pool autoscaler** over discrete instances, and billing switches from
provisioned-seconds to **warm-instance-seconds**, so cost finally differs
across allocation policies, capacity policies, workloads, and topologies.

Semantics (threaded identically through ``simulator.simulate_core``, the
numpy oracle ``reference_sim.simulate_numpy``, and the serving engine
``serving/engine.py``):

* The pool holds ``warm`` instances (each contributes 1.0 to the allocator's
  budget: ``g_total(t) = warm(t)``) plus ``pending`` instances still cold.
* Every step a registered **capacity policy** observes the fleet-wide state
  (total intake, its EMA forecast, total backlog, idle time) and returns a
  desired warm count.  Scale-down is instantaneous; scale-up requests enter
  a cold-start pipeline and serve nothing for ``round(cold_start_s)`` steps
  (in-flight instances cannot be cancelled — they warm up and are trimmed by
  the next scale-down decision, exactly like real serverless pools).
* ``SimConfig.num_gpus`` is the **instance ceiling**: no capacity policy may
  exceed it, and static budgets are rejected when ``g_total > num_gpus``.

Registered capacity policies (the registry mirrors the allocation-policy
registry in ``core/allocator.py`` — a traced integer id dispatched with
``lax.switch``, so a *batched capacity axis* is plain ``vmap`` over a
``stack_capacities`` pytree, see ``core/sweep.py::sweep_capacity``):

* ``fixed``         — always-on pool of exactly ``g_total`` instances; with
                      ``cold_start_s = 0`` this reproduces the pre-capacity
                      static-budget trajectories **bit-for-bit** (the no-op
                      guarantee, regression-tested for every allocation
                      policy in tests/test_capacity.py).
* ``reactive``      — queue/rate-threshold scaling: enough instances to
                      absorb the EMA arrival rate at
                      ``target_rate_per_instance`` rps each, plus one extra
                      instance per ``backlog_per_instance`` queued requests,
                      floored at ``min_instances``.
* ``scale_to_zero`` — the reactive rule with a keep-alive window: while any
                      demand (intake or backlog) is present the pool keeps
                      at least one instance; once the fleet has been idle
                      longer than ``keep_alive_s`` the pool drops to zero
                      and billing stops entirely.

``billing_cost`` is the single billing formula for the whole codebase
(simulator metrics, sweep grids, the serving engine): instance-seconds →
dollars.  The pre-capacity code triplicated ``num_gpus · steps / 3600 ·
price`` across simulator.py and three sweep call sites; every path now
funnels through this helper with warm-instance-seconds as the input.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-9

# Static length of the cold-start delay line: one slot per whole second a
# requested instance can still be cold.  ``cold_start_s`` is validated
# against this bound eagerly (check_capacity) so the traced scatter below
# never silently clips a longer delay.
COLD_START_HORIZON = 32


def billing_cost(instance_seconds, price_per_hour: float):
    """Dollars for ``instance_seconds`` of warm capacity — THE billing
    formula (jnp-safe: traced instance-seconds bill inside jit).

    Provisioned billing is the special case ``instance_seconds =
    num_gpus · duration``; serverless billing passes ``Σ_t warm(t) · 1 s``.
    """
    return instance_seconds / 3600.0 * price_per_hour


def check_budget_ceiling(g_total: float, num_gpus: float) -> None:
    """THE ceiling invariant: a static budget that could never be
    provisioned under its own instance ceiling is a config error.  Shared
    by ``SimConfig``, ``check_capacity`` and the serving engine."""
    if g_total > num_gpus:
        raise ValueError(
            f"g_total={g_total} exceeds the instance ceiling num_gpus={num_gpus}"
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CapacityConfig:
    """One capacity policy + its knobs, as a registered pytree.

    Every field (including the policy selector) is a scalar *leaf*, so a
    list of heterogeneous configs stacks into one batched pytree
    (``stack_capacities``) and the whole capacity axis vmaps through the
    sweep grid; ``name`` is display-only static aux data.
    """

    policy_id: jnp.ndarray                 # () int32, capacity-registry index
    cold_start_s: jnp.ndarray              # () f32, seconds pending before warm
    keep_alive_s: jnp.ndarray              # () f32, idle window (scale_to_zero)
    target_rate_per_instance: jnp.ndarray  # () f32, rps one instance absorbs
    backlog_per_instance: jnp.ndarray      # () f32, queued reqs per extra instance
    min_instances: jnp.ndarray             # () f32, reactive floor
    name: str = "capacity"

    def tree_flatten(self):
        return (
            (self.policy_id, self.cold_start_s, self.keep_alive_s,
             self.target_rate_per_instance, self.backlog_per_instance,
             self.min_instances),
            self.name,
        )

    @classmethod
    def tree_unflatten(cls, name, children):
        return cls(*children, name=name)

    @property
    def policy(self) -> str:
        """Registry name of the selected capacity policy (host-side)."""
        pid = np.asarray(self.policy_id)
        if pid.ndim != 0:
            raise ValueError(
                f"config {self.name!r} is a stacked batch of {pid.shape[0]} "
                "policies; index the batch (or keep the unstacked configs) "
                "to read a single policy name"
            )
        return capacity_policy_names()[int(pid)]


def capacity_config(
    policy: str = "fixed",
    *,
    cold_start_s: float = 0.0,
    keep_alive_s: float = 10.0,
    target_rate_per_instance: float = 60.0,
    backlog_per_instance: float = 50.0,
    min_instances: float = 0.0,
    name: str | None = None,
) -> CapacityConfig:
    """Build a ``CapacityConfig`` by capacity-policy name.

    Defaults are sized for the paper fleet: one instance serves ~60 rps
    (Table II's aggregate throughput at g = 1), and ~50 queued requests
    justify warming an extra instance.
    """
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    return CapacityConfig(
        policy_id=jnp.asarray(capacity_policy_id(policy), jnp.int32),
        cold_start_s=f32(cold_start_s),
        keep_alive_s=f32(keep_alive_s),
        target_rate_per_instance=f32(target_rate_per_instance),
        backlog_per_instance=f32(backlog_per_instance),
        min_instances=f32(min_instances),
        name=policy if name is None else name,
    )


def check_capacity(cap: CapacityConfig, g_total: float, num_gpus: float) -> None:
    """Eager (outside-jit) sanity constraints for one config or a stacked
    batch of configs (leaves may carry a leading capacity axis)."""
    cold = np.asarray(cap.cold_start_s)
    if (cold < 0).any() or (cold > COLD_START_HORIZON - 1).any():
        raise ValueError(
            f"cold_start_s must be in [0, {COLD_START_HORIZON - 1}] "
            f"(COLD_START_HORIZON), got {cold}"
        )
    if (np.asarray(cap.keep_alive_s) < 0).any():
        raise ValueError(f"keep_alive_s must be >= 0: {np.asarray(cap.keep_alive_s)}")
    if (np.asarray(cap.target_rate_per_instance) <= 0).any():
        raise ValueError("target_rate_per_instance must be positive")
    if (np.asarray(cap.backlog_per_instance) <= 0).any():
        raise ValueError("backlog_per_instance must be positive")
    mins = np.asarray(cap.min_instances)
    if (mins < 0).any() or (mins > num_gpus).any():
        raise ValueError(
            f"min_instances must be in [0, num_gpus={num_gpus}]: {mins}"
        )
    check_budget_ceiling(g_total, num_gpus)


def stack_capacities(caps: Sequence[CapacityConfig]) -> CapacityConfig:
    """Stack configs on a new leading capacity axis: every leaf becomes
    (C,), ready for ``vmap`` (``core/sweep.py::sweep_capacity``).  Stacked
    field-wise rather than via ``tree_map`` so per-config display names
    (static aux data) are allowed to differ."""
    caps = list(caps)
    if not caps:
        raise ValueError("stack_capacities needs at least one config")
    stack = lambda field: jnp.stack([getattr(c, field) for c in caps])
    return CapacityConfig(
        policy_id=stack("policy_id"),
        cold_start_s=stack("cold_start_s"),
        keep_alive_s=stack("keep_alive_s"),
        target_rate_per_instance=stack("target_rate_per_instance"),
        backlog_per_instance=stack("backlog_per_instance"),
        min_instances=stack("min_instances"),
        name="stacked",
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CapacityState:
    """The warm pool's scan-carry state.

    ``pipeline[k]`` is the number of requested instances that become warm in
    ``k`` steps (a fixed-length delay line of cohorts); ``idle_s`` counts
    consecutive seconds with zero fleet-wide demand (the keep-alive clock).
    """

    warm: jnp.ndarray      # () f32, serving instances
    pipeline: jnp.ndarray  # (COLD_START_HORIZON,) f32, cold cohorts
    idle_s: jnp.ndarray    # () f32

    def tree_flatten(self):
        return (self.warm, self.pipeline, self.idle_s), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_capacity_state(g_total: float) -> CapacityState:
    """The pool at t=0: the provisioned baseline is already warm (the
    ``fixed`` policy therefore never transitions — the no-op guarantee)."""
    return CapacityState(
        warm=jnp.asarray(g_total, jnp.float32),
        pipeline=jnp.zeros((COLD_START_HORIZON,), jnp.float32),
        idle_s=jnp.zeros((), jnp.float32),
    )


# ---------------------------------------------------------------------------
# Capacity-policy registry — mirrors the allocation-policy registry.
#
# Uniform signature:
#   (t, lam_tot, lam_ema_tot, queue_tot, warm, pending, idle_s,
#    cap, g_total, num_gpus) -> desired warm count (traced scalar)
# ---------------------------------------------------------------------------

CapacityPolicyFn = Callable[..., jnp.ndarray]

_CAP_REGISTRY: dict[str, CapacityPolicyFn] = {}


def register_capacity_policy(name: str) -> Callable[[CapacityPolicyFn], CapacityPolicyFn]:
    def deco(fn: CapacityPolicyFn) -> CapacityPolicyFn:
        if name in _CAP_REGISTRY:
            raise ValueError(f"capacity policy {name!r} already registered")
        _CAP_REGISTRY[name] = fn
        return fn

    return deco


def capacity_policy_names() -> tuple[str, ...]:
    """All registered capacity policies, in registration (= id) order."""
    return tuple(_CAP_REGISTRY)


def capacity_policy_id(name: str) -> int:
    if name not in _CAP_REGISTRY:
        raise ValueError(
            f"unknown capacity policy {name!r}; registered: "
            f"{capacity_policy_names()}"
        )
    return capacity_policy_names().index(name)


def capacity_switch(
    policy_id: jnp.ndarray,
    t: jnp.ndarray,
    lam_tot: jnp.ndarray,
    lam_ema_tot: jnp.ndarray,
    queue_tot: jnp.ndarray,
    warm: jnp.ndarray,
    pending: jnp.ndarray,
    idle_s: jnp.ndarray,
    cap: CapacityConfig,
    g_total: float,
    num_gpus: float,
) -> jnp.ndarray:
    """Traced dispatch over the capacity registry (``lax.switch``)."""
    branches = tuple(
        (lambda fn=fn: fn(t, lam_tot, lam_ema_tot, queue_tot, warm, pending,
                          idle_s, cap, g_total, num_gpus))
        for fn in _CAP_REGISTRY.values()
    )
    return jax.lax.switch(policy_id, branches)


def _reactive_desired(lam_ema_tot, queue_tot, cap):
    """Discrete queue/rate-threshold rule shared by the elastic policies:
    whole instances for the forecast rate, whole extra instances for the
    standing backlog."""
    rate_need = jnp.ceil(
        lam_ema_tot / jnp.maximum(cap.target_rate_per_instance, _EPS)
    )
    backlog_boost = jnp.floor(
        queue_tot / jnp.maximum(cap.backlog_per_instance, _EPS)
    )
    return rate_need + backlog_boost


@register_capacity_policy("fixed")
def _fixed(t, lam_tot, lam_ema_tot, queue_tot, warm, pending, idle_s, cap,
           g_total, num_gpus):
    """Always-on provisioned pool — the pre-capacity static budget."""
    return jnp.asarray(g_total, jnp.float32)


@register_capacity_policy("reactive")
def _reactive(t, lam_tot, lam_ema_tot, queue_tot, warm, pending, idle_s, cap,
              g_total, num_gpus):
    desired = _reactive_desired(lam_ema_tot, queue_tot, cap)
    return jnp.clip(desired, cap.min_instances, num_gpus)


@register_capacity_policy("scale_to_zero")
def _scale_to_zero(t, lam_tot, lam_ema_tot, queue_tot, warm, pending, idle_s,
                   cap, g_total, num_gpus):
    """Reactive scaling that releases the whole pool after ``keep_alive_s``
    idle seconds; while any demand is present the busy-path floor is
    ``max(min_instances, 1)`` — the configured reactive floor still binds,
    scale-to-zero only overrides it once the keep-alive window expires."""
    desired = _reactive_desired(lam_ema_tot, queue_tot, cap)
    floor = jnp.maximum(cap.min_instances, 1.0)
    active_desired = jnp.clip(desired, floor, num_gpus)
    return jnp.where(idle_s <= cap.keep_alive_s, active_desired, 0.0)


def capacity_step(
    state: CapacityState,
    cap: CapacityConfig,
    t: jnp.ndarray,
    lam_tot: jnp.ndarray,
    lam_ema_tot: jnp.ndarray,
    queue_tot: jnp.ndarray,
    g_total: float,
    num_gpus: float,
) -> tuple[CapacityState, jnp.ndarray, jnp.ndarray]:
    """One autoscaler tick; returns ``(new_state, warm, pending)`` where
    ``warm`` is the step's allocator budget ``g_total(t)``.

    Order within a step: (1) cohorts whose cold start elapsed become warm,
    (2) the idle clock advances, (3) the capacity policy picks a desired
    count, (4) scale-down is instantaneous, (5) missing instances (beyond
    warm + pending) are requested and enter the delay line at
    ``round(cold_start_s)`` — a zero cold start serves the same step.
    """
    warm = state.warm + state.pipeline[0]
    pipeline = jnp.concatenate([state.pipeline[1:], jnp.zeros((1,), jnp.float32)])
    busy = (lam_tot + queue_tot) > 0
    idle_s = jnp.where(busy, 0.0, state.idle_s + 1.0)
    pending = pipeline.sum()
    desired = capacity_switch(
        cap.policy_id, t, lam_tot, lam_ema_tot, queue_tot, warm, pending,
        idle_s, cap, g_total, num_gpus,
    )
    warm = jnp.minimum(warm, desired)
    request = jnp.maximum(desired - (warm + pending), 0.0)
    delay = jnp.clip(
        jnp.round(cap.cold_start_s), 0, COLD_START_HORIZON - 1
    ).astype(jnp.int32)
    direct = jnp.where(delay == 0, request, 0.0)
    warm = warm + direct
    # Slot k is consumed at the start of step t+k+1, so a d-second cold
    # start lands in slot d-1 (d = 0 was served directly above).
    slot = jnp.maximum(delay - 1, 0)
    pipeline = pipeline + jax.nn.one_hot(
        slot, COLD_START_HORIZON, dtype=jnp.float32
    ) * (request - direct)
    new_state = CapacityState(warm=warm, pipeline=pipeline, idle_s=idle_s)
    return new_state, warm, pipeline.sum()
