"""Independent pure-numpy oracle of the fleet simulator.

A second, deliberately naive implementation of the queue dynamics (python
loops, float64) used by property tests to cross-validate the vectorized
``lax.scan`` simulator — the same oracle pattern the Pallas kernels use
(ref.py vs kernel).  Covers the **entire** policy registry (including
``throughput_greedy`` and ``objective_descent``, whose projected-gradient
loop is re-derived here with a hand-written analytic gradient rather than
``jax.grad``) and the workflow-routing path: when a ``Workflow`` is given,
exogenous arrivals feed only source agents and each step's served requests
are forwarded into downstream queues for the next step, exactly as in
``simulator.simulate_core``.

The serverless capacity layer (``core/capacity.py``) is re-implemented here
as an explicit python loop over the warm pool: cohorts leave a plain list
delay line, the idle clock and the keep-alive window are straight-line
float64 arithmetic, and the allocator's budget each step is the loop's own
``warm`` — so the oracle cross-validates the JAX scan under ``reactive``
and ``scale_to_zero`` autoscaling, not just the static budget.
"""
from __future__ import annotations

import numpy as np

from repro.core.agents import Fleet
from repro.core.capacity import (
    COLD_START_HORIZON,
    CapacityConfig,
    capacity_policy_names,
)
from repro.core.routing import Workflow

_EPS = 1e-9


def synthesize_loop(
    spec, num_steps: int | None = None, block_size: int = 1
) -> np.ndarray:
    """Eager python-loop twin of ``workload.materialize``.

    Walks the registered generator one step at a time — ``workload_step``
    called eagerly per t, state threaded through a plain python variable —
    so the ``lax.scan`` in ``materialize`` (and therefore the in-scan
    synthesis arm of the streaming kernel, which runs the *same* step
    functions) is cross-validated by a second control-flow path, exactly
    like this module's queue-dynamics loop cross-validates the simulator
    scan.  Returns the (S, N) arrival tensor as float64 rows.

    ``block_size`` > 1 is the eager twin of the *time-blocked* kernel: the
    horizon is walked ⌈S/B⌉ blocks at a time through ``workload.step_block``
    — a python outer loop in place of the kernel's outer scan, the block
    state threaded by hand, and a naturally ragged tail block (no masking
    needed eagerly) — so block decomposition is cross-validated by a second
    control-flow frame too.  Every B yields identical rows.
    """
    from repro.core import workload as workload_mod

    steps = int(spec.num_steps if num_steps is None else num_steps)
    b = int(block_size)
    if b < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    state = workload_mod.workload_init(spec)
    rows = []
    if b == 1:
        for t in range(steps):
            lam, state = workload_mod.workload_step(spec, state, t)
            rows.append(np.asarray(lam, np.float64))
        return np.stack(rows)
    import jax.numpy as jnp

    for t0 in range(0, steps, b):
        ts = jnp.arange(t0, min(t0 + b, steps), dtype=jnp.int32)
        lam_rows, state = workload_mod.step_block(spec, state, ts)
        rows.append(np.asarray(lam_rows, np.float64))
    return np.concatenate(rows)


# Every registry entry the oracle reproduces; kept in sync with
# ``allocator.policy_names()`` by tests/test_reference_sim.py.
SUPPORTED_POLICIES = (
    "static_equal",
    "round_robin",
    "adaptive",
    "water_filling",
    "predictive",
    "throughput_greedy",
    "objective_descent",
    "sqrt_demand",
    "ema_water_filling",
)


def _normalize(g: np.ndarray, g_total: float) -> np.ndarray:
    """Proportional scale-down iff over capacity (Algorithm 1 lines 19-25)."""
    if g.sum() > g_total:
        g = g * (g_total / max(g.sum(), _EPS))
    return g


def _adaptive(src: np.ndarray, R: np.ndarray, P: np.ndarray, g_total: float) -> np.ndarray:
    d = src * R / P
    if d.sum() <= 0:
        return np.zeros_like(src)
    g = np.maximum(R, d / d.sum() * g_total)
    return _normalize(g, g_total)


def _water_fill(pressure: np.ndarray, R: np.ndarray, g_total: float) -> np.ndarray:
    """Shared water-filling shape: proportional-to-pressure with a busy
    min-GPU floor, used by ``water_filling`` (pressure from observed
    intake), ``ema_water_filling`` (pressure from the EMA forecast) and —
    through a sqrt of the pressure — ``sqrt_demand``."""
    if pressure.sum() <= 0:
        return np.zeros_like(pressure)
    prop = pressure / pressure.sum() * g_total
    g = np.maximum(np.where(pressure > 0, R, 0.0), prop)
    return _normalize(g, g_total)


def _throughput_greedy(
    q: np.ndarray, lam: np.ndarray, T: np.ndarray, R: np.ndarray, g_total: float
) -> np.ndarray:
    x = q + lam
    busy = x > 0
    g = np.where(busy, R, 0.0)
    need = np.where(busy, x / np.maximum(T, _EPS), 0.0)
    extra = np.maximum(need - g, 0.0)
    residual = max(g_total - g.sum(), 0.0)
    # Highest-throughput agents first; stable sort matches jnp.argsort.
    order = np.argsort(-T, kind="stable")
    sorted_need = extra[order]
    cum_before = np.cumsum(sorted_need) - sorted_need
    grant_sorted = np.clip(residual - cum_before, 0.0, sorted_need)
    grant = np.zeros_like(g)
    grant[order] = grant_sorted
    return _normalize(g + grant, g_total)


def _objective_descent(
    q: np.ndarray,
    lam: np.ndarray,
    T: np.ndarray,
    R: np.ndarray,
    P: np.ndarray,
    g_total: float,
    alpha: float = 1.0,
    gamma: float = 10.0,
    steps: int = 12,
    lr: float = 0.05,
    latency_cap: float = 1000.0,
) -> np.ndarray:
    """Projected gradient descent on the one-step Eq. (2) lookahead, with
    the gradient derived by hand (the oracle must not depend on jax.grad).

    Kinks (min/max ties) get the 0.5/0.5 split JAX's ``lax.min``/``lax.max``
    use, so the two implementations agree even on the measure-zero tie set.
    """
    n = len(T)
    x = q + lam
    busy = x > 0
    if not busy.any():
        return np.zeros(n)
    floor = np.where(busy, R, 0.0)

    def project(g):
        return _normalize(np.clip(g, floor, 1.0), g_total)

    def grad(g):
        c = g * T
        denom = np.maximum(c, 1e-6)
        served = np.minimum(c, x)
        new_q = x - served
        r = new_q / denom
        ds_dc = np.where(c < x, 1.0, np.where(c > x, 0.0, 0.5))
        dden_dc = np.where(c > 1e-6, 1.0, np.where(c < 1e-6, 0.0, 0.5))
        dr_dc = (-ds_dc * denom - new_q * dden_dc) / denom**2
        dlat_dc = dr_dc * np.where(
            r < latency_cap, 1.0, np.where(r > latency_cap, 0.0, 0.5)
        )
        return (alpha * dlat_dc / n - gamma * ds_dc) * T

    g = project(_adaptive(lam, R, P, g_total))
    for _ in range(steps):
        g = project(g - lr * grad(g))
    return g


def _capacity_desired(
    name: str,
    ema_tot: float,
    q_tot: float,
    idle_s: float,
    keep_alive_s: float,
    target_rate: float,
    backlog_per: float,
    min_instances: float,
    g_total: float,
    num_gpus: float,
) -> float:
    """The registry's three capacity rules, straight-line python.  The
    cold-start delay is not an input: it shapes *when* a request warms
    (the caller's delay line), never how many instances are desired."""
    if name == "fixed":
        return g_total
    rate_need = np.ceil(ema_tot / max(target_rate, _EPS))
    backlog_boost = np.floor(q_tot / max(backlog_per, _EPS))
    desired = rate_need + backlog_boost
    if name == "reactive":
        return float(np.clip(desired, min_instances, num_gpus))
    if name == "scale_to_zero":
        floor = max(min_instances, 1.0)
        active_desired = float(np.clip(desired, floor, num_gpus))
        return active_desired if idle_s <= keep_alive_s else 0.0
    raise ValueError(
        f"unknown capacity policy {name!r}; oracle supports "
        f"{capacity_policy_names()}"
    )


def simulate_numpy(
    policy: str,
    arrivals: np.ndarray,
    fleet: Fleet,
    g_total: float = 1.0,
    latency_cap: float = 1000.0,
    ema_alpha: float = 0.3,
    workflow: Workflow | None = None,
    capacity: CapacityConfig | None = None,
    num_gpus: float = 1.0,
    failures=None,
) -> dict:
    """Returns per-step arrays matching SimTrace semantics (plus
    ``completed``, the requests exiting the workflow at each agent,
    ``warm``/``pending``, the warm pool's trajectory, ``misrouted``, and —
    under a ``failures`` spec — ``dropped``/``retried``/``expired``/
    ``recovery``).

    The failure layer (``core/failures.py``) is re-implemented here as
    straight-line float64 python: the revocation/outage Markov chains are
    replayed from the *same* counter-based uniforms the scan draws
    (``failures.failure_uniforms`` is pure in ``t``, so both control-flow
    frames see identical chains — comparisons on identical floats are
    exact), and the deadline/retry class bookkeeping is an eager mirror of
    ``failures.deadline_step``."""
    if policy not in SUPPORTED_POLICIES:
        raise ValueError(
            f"unknown policy {policy!r}; oracle supports {SUPPORTED_POLICIES}"
        )
    T = np.asarray(fleet.base_throughput, np.float64)
    R = np.asarray(fleet.min_gpu, np.float64)
    P = np.asarray(fleet.priority, np.float64)
    n = len(T)
    steps = arrivals.shape[0]
    active = np.asarray(fleet.active, np.float64)
    if workflow is None:
        route = np.zeros((n, n))
        source = np.ones(n)
        fan_out = np.ones(n)
    else:
        route = np.asarray(workflow.route, np.float64)
        source = np.asarray(workflow.source, np.float64)
        fan_out = np.asarray(workflow.fan_out, np.float64)
    exit_frac = np.maximum(1.0 - route.sum(axis=1), 0.0)
    # Same gating as the scan: exogenous arrivals enter only at active
    # source agents, routed mass never wakes a padded slot.  (The policy
    # branches themselves are mask-unaware — the oracle cross-validates
    # unpadded fleets; padded-fleet parity is the registry's job.)
    arrivals = np.asarray(arrivals, np.float64) * source[None, :] * active[None, :]

    if capacity is not None:
        cap_name = capacity_policy_names()[int(capacity.policy_id)]
        cold_start_s = float(np.asarray(capacity.cold_start_s))
        keep_alive_s = float(np.asarray(capacity.keep_alive_s))
        target_rate = float(np.asarray(capacity.target_rate_per_instance))
        backlog_per = float(np.asarray(capacity.backlog_per_instance))
        min_instances = float(np.asarray(capacity.min_instances))
        delay = int(np.clip(np.round(cold_start_s), 0, COLD_START_HORIZON - 1))
    warm = float(g_total)
    pipeline = np.zeros(COLD_START_HORIZON)
    idle_s = 0.0

    if failures is not None:
        from repro.core.failures import RETRY_CLASSES, failure_uniforms

        C = RETRY_CLASSES
        f_rev_enter = float(np.asarray(failures.revoke_p_enter))
        f_rev_exit = float(np.asarray(failures.revoke_p_exit))
        f_rev_frac = float(np.asarray(failures.revoke_frac))
        f_down_enter = float(np.asarray(failures.fail_p_enter))
        f_down_exit = float(np.asarray(failures.fail_p_exit))
        f_out_start = float(np.asarray(failures.outage_start))
        f_out_len = float(np.asarray(failures.outage_len))
        f_out_agent = float(np.asarray(failures.outage_agent))
        deadline = np.broadcast_to(
            np.asarray(failures.deadline_s, np.float64), (n,)
        ).copy()
        budget = float(np.clip(np.asarray(failures.retry_budget), 0, C - 1))
        rev_on = 0.0
        down = np.zeros(n)
        fail_prev = 0.0
        recovering = 0.0
        q_mark = 0.0
        retry_q = np.zeros((C - 1, n))

    q = np.zeros(n)
    endo = np.zeros(n)
    ema = arrivals[0].copy()
    out = {"allocation": [], "served": [], "queue": [], "latency": [],
           "completed": [], "warm": [], "pending": [], "misrouted": [],
           "dropped": [], "retried": [], "expired": [], "recovery": []}

    for t in range(steps):
        lam = arrivals[t] + endo  # total intake: exogenous + routed
        # EMA is seeded with the first observation; applying the update
        # again at t=0 would double-count it.
        if t > 0:
            ema = ema_alpha * lam + (1 - ema_alpha) * ema
        if capacity is not None:
            # Same step order as capacity.capacity_step: warm-ups, idle
            # clock, decision, instant scale-down, cold-start requests.
            warm += pipeline[0]
            pipeline = np.append(pipeline[1:], 0.0)
            idle_s = 0.0 if (lam.sum() + q.sum()) > 0 else idle_s + 1.0
            pending = pipeline.sum()
            desired = _capacity_desired(
                cap_name, ema.sum(), q.sum(), idle_s, keep_alive_s,
                target_rate, backlog_per, min_instances, g_total, num_gpus,
            )
            warm = min(warm, desired)
            request = max(desired - (warm + pending), 0.0)
            if delay == 0:
                warm += request
            else:
                # slot k warms at step t+k+1: a d-second delay is slot d-1
                pipeline[delay - 1] += request
            g_total_t = warm
            pending_t = pipeline.sum()
        else:
            g_total_t = g_total
            pending_t = 0.0
        if policy == "static_equal":
            g = np.full(n, g_total_t / n)
        elif policy == "round_robin":
            g = np.zeros(n)
            g[t % n] = g_total_t
        elif policy in ("adaptive", "predictive"):
            g = _adaptive(lam if policy == "adaptive" else ema, R, P, g_total_t)
        elif policy == "water_filling":
            g = _water_fill((q + lam) / np.maximum(T, _EPS), R, g_total_t)
        elif policy == "ema_water_filling":
            g = _water_fill((q + ema) / np.maximum(T, _EPS), R, g_total_t)
        elif policy == "sqrt_demand":
            g = _water_fill(
                np.sqrt((q + lam) / np.maximum(T, _EPS)), R, g_total_t
            )
        elif policy == "throughput_greedy":
            g = _throughput_greedy(q, lam, T, R, g_total_t)
        else:  # objective_descent
            # NB: the registry entry always runs the policy's internal
            # latency_cap default (1000), independent of the sim-level cap.
            g = _objective_descent(q, lam, T, R, P, g_total_t)
        if failures is None:
            cap = g * T
            served = np.minimum(cap, q + lam)
            q = q + lam - served
            lat = np.minimum(q / np.maximum(cap, _EPS), latency_cap)
            dropped = retried = expired = np.zeros(n)
            in_rec = 0.0
        else:
            # Replay the chains from the scan's own uniforms (exact).
            u_rev, u_down = failure_uniforms(failures, t, n)
            u_rev = float(np.asarray(u_rev))
            u_down = np.asarray(u_down, np.float64)
            rev_on = float(
                (u_rev >= f_rev_exit) if rev_on > 0.5 else (u_rev < f_rev_enter)
            )
            down = np.where(down > 0.5, u_down >= f_down_exit,
                            u_down < f_down_enter).astype(np.float64)
            phi = f_rev_frac * rev_on
            sched = 1.0 if f_out_start <= t < f_out_start + f_out_len else 0.0
            col = (np.arange(n) == f_out_agent).astype(np.float64)
            down_eff = np.clip(down + sched * col, 0.0, 1.0)
            up = 1.0 - down_eff
            fail_t = float(max(float(phi > 0),
                               float(((down_eff * active) > 0.5).any())))
            pre_q_tot = float((q * active).sum())
            onset = fail_t * (1.0 - fail_prev) * (1.0 - recovering)
            if onset > 0:
                q_mark = pre_q_tot
            # Failure-aware physics (mirror of _failure_queue_step).
            cap = g * up * T
            served_raw = np.minimum(cap, q + lam)
            served = served_raw * (1.0 - phi)
            q_post = q + lam - served
            cap_eff = cap * (1.0 - phi)
            # Deadline/retry class bookkeeping (mirror of deadline_step).
            enabled = (deadline > 0).astype(np.float64)
            expired = enabled * np.maximum(
                q_post - cap_eff * np.maximum(deadline, 0.0), 0.0
            )
            x = q + lam
            f_surv = q_post / np.maximum(x, _EPS)
            m0 = np.maximum(x - retry_q.sum(axis=0), 0.0)
            m = np.vstack([m0[None, :], retry_q])
            m_post = m * f_surv[None, :]
            exp_frac = expired / np.maximum(q_post, _EPS)
            e = m_post * exp_frac[None, :]
            retry_mask = (np.arange(C) < budget).astype(np.float64)[:, None]
            ret = e * retry_mask
            dro = e * (1.0 - retry_mask)
            promoted = np.vstack([np.zeros((1, n)), ret[:-1]])
            new_m = (m_post - e) + promoted
            retry_q = new_m[1:]
            dropped = dro.sum(axis=0)
            retried = ret.sum(axis=0)
            q = q_post - dropped
            # Dead-band snap mirrors _failure_queue_step: roundoff residue
            # around an exactly-drained queue must not flip queue>0
            # branches (greedy allocators) or the clipped-latency cliff
            # across float widths.
            q = q * (q > 1e-4)
            lat = np.minimum(q / np.maximum(cap_eff, _EPS), latency_cap) * (
                q > 1e-4
            )
            # Recovery bookkeeping.
            new_q_tot = float((q * active).sum())
            in_rec = (1.0 - fail_t) * max(fail_prev, recovering)
            recovering = recovering if fail_t > 0 else (
                in_rec * float(new_q_tot > q_mark)
            )
            fail_prev = fail_t
            if capacity is not None:
                # Revoked instances leave the warm pool; the autoscaler
                # re-provisions them through the cold-start line next step.
                warm *= (1.0 - phi)
            # Billing excludes revoked instance-seconds (as in the kernels).
            g_total_t = g_total_t * (1.0 - phi)
        fwd = (served * fan_out) @ route
        endo = fwd * active
        out["allocation"].append(g.copy())
        out["served"].append(served.copy())
        out["queue"].append(q.copy())
        out["latency"].append(lat.copy())
        out["completed"].append(served * exit_frac)
        out["warm"].append(g_total_t)
        out["pending"].append(pending_t)
        out["misrouted"].append(fwd * (1.0 - active))
        out["dropped"].append(np.asarray(dropped, np.float64).copy()
                              if failures is not None else np.zeros(n))
        out["retried"].append(np.asarray(retried, np.float64).copy()
                              if failures is not None else np.zeros(n))
        out["expired"].append(np.asarray(expired, np.float64).copy()
                              if failures is not None else np.zeros(n))
        out["recovery"].append(float(in_rec))
    return {k: np.asarray(v) for k, v in out.items()}
