"""Independent pure-numpy oracle of the fleet simulator.

A second, deliberately naive implementation of the queue dynamics (python
loops, float64) used by property tests to cross-validate the vectorized
``lax.scan`` simulator — the same oracle pattern the Pallas kernels use
(ref.py vs kernel).
"""
from __future__ import annotations

import numpy as np

from repro.core.agents import Fleet

_EPS = 1e-9


def simulate_numpy(
    policy: str,
    arrivals: np.ndarray,
    fleet: Fleet,
    g_total: float = 1.0,
    latency_cap: float = 1000.0,
    ema_alpha: float = 0.3,
) -> dict:
    """Returns per-step arrays matching SimTrace semantics."""
    T = np.asarray(fleet.base_throughput, np.float64)
    R = np.asarray(fleet.min_gpu, np.float64)
    P = np.asarray(fleet.priority, np.float64)
    n = len(T)
    steps = arrivals.shape[0]
    q = np.zeros(n)
    ema = np.asarray(arrivals[0], np.float64).copy()
    out = {"allocation": [], "served": [], "queue": [], "latency": []}

    for t in range(steps):
        lam = np.asarray(arrivals[t], np.float64)
        # EMA is seeded with arrivals[0]; applying the update again at t=0
        # would double-count the first observation.
        if t > 0:
            ema = ema_alpha * lam + (1 - ema_alpha) * ema
        if policy == "static_equal":
            g = np.full(n, g_total / n)
        elif policy == "round_robin":
            g = np.zeros(n)
            g[t % n] = g_total
        elif policy in ("adaptive", "predictive"):
            src = lam if policy == "adaptive" else ema
            d = src * R / P
            if d.sum() <= 0:
                g = np.zeros(n)
            else:
                g = np.maximum(R, d / d.sum() * g_total)
                if g.sum() > g_total:
                    g = g * (g_total / g.sum())
        elif policy == "water_filling":
            pressure = (q + lam) / np.maximum(T, _EPS)
            if pressure.sum() <= 0:
                g = np.zeros(n)
            else:
                prop = pressure / pressure.sum() * g_total
                g = np.maximum(np.where(pressure > 0, R, 0.0), prop)
                if g.sum() > g_total:
                    g = g * (g_total / g.sum())
        else:
            raise ValueError(policy)
        cap = g * T
        served = np.minimum(cap, q + lam)
        q = q + lam - served
        lat = np.minimum(q / np.maximum(cap, _EPS), latency_cap)
        out["allocation"].append(g.copy())
        out["served"].append(served.copy())
        out["queue"].append(q.copy())
        out["latency"].append(lat.copy())
    return {k: np.asarray(v) for k, v in out.items()}
