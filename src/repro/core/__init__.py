"""Paper core: adaptive GPU allocation + fleet simulation (pure JAX)."""
from repro.core.agents import (
    AgentSpec,
    Fleet,
    PAPER_ARRIVAL_RATES,
    T4_PRICE_PER_HOUR,
    paper_fleet,
)
from repro.core.allocator import (
    POLICY_NAMES,
    adaptive_allocation,
    predictive_adaptive,
    round_robin,
    static_equal,
    throughput_greedy,
    water_filling,
)
from repro.core import workload
from repro.core.objective import ObjectiveWeights, step_objective
from repro.core.simulator import (
    POLICY_IDS,
    SimConfig,
    SimSummary,
    SimTrace,
    run_policy,
    simulate,
    summarize,
)

__all__ = [
    "AgentSpec", "Fleet", "PAPER_ARRIVAL_RATES", "T4_PRICE_PER_HOUR",
    "paper_fleet", "POLICY_NAMES", "adaptive_allocation", "predictive_adaptive",
    "round_robin", "static_equal", "throughput_greedy", "water_filling",
    "ObjectiveWeights", "step_objective", "POLICY_IDS", "SimConfig",
    "SimSummary", "SimTrace", "run_policy", "simulate", "summarize", "workload",
]
