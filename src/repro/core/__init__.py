"""Paper core: adaptive GPU allocation + fleet simulation (pure JAX)."""
from repro.core.agents import (
    AgentSpec,
    Fleet,
    PAPER_ARRIVAL_RATES,
    T4_PRICE_PER_HOUR,
    pad_fleet,
    paper_fleet,
    scale_fleet,
    stack_fleets,
    synthetic_fleet,
)
from repro.core.allocator import (
    adaptive_allocation,
    dispatch,
    get_policy,
    policy_id,
    policy_names,
    policy_stack,
    policy_switch,
    predictive_adaptive,
    register_policy,
    round_robin,
    static_equal,
    throughput_greedy,
    water_filling,
)
from repro.core import capacity
from repro.core import routing
from repro.core import workload
from repro.core.capacity import (
    CapacityConfig,
    CapacityState,
    billing_cost,
    capacity_config,
    capacity_policy_id,
    capacity_policy_names,
    check_capacity,
    register_capacity_policy,
    stack_capacities,
)
from repro.core.objective import ObjectiveWeights, step_objective
from repro.core.routing import (
    Workflow,
    coordinator_star,
    hierarchical,
    independent_workflow,
    pad_workflow,
    pipeline_chain,
    stack_workflows,
    synthetic_workflow,
)
from repro.core.simulator import (
    METRIC_NAMES,
    MetricAccum,
    SimConfig,
    SimSummary,
    SimTrace,
    accumulate_metrics,
    finalize_metrics,
    init_metric_accum,
    run_policy,
    simulate,
    simulate_core,
    simulate_stream_core,
    summarize,
    trace_metrics,
)
from repro.core.sweep import (
    Scenario,
    SweepResult,
    SweepSummary,
    capacity_scenario_library,
    fleet_scenario_library,
    scenario_library,
    sweep,
    sweep_capacity,
    sweep_fleets,
    sweep_workflows,
    workflow_scenario_library,
)

__all__ = [
    "AgentSpec", "Fleet", "PAPER_ARRIVAL_RATES", "T4_PRICE_PER_HOUR",
    "paper_fleet", "pad_fleet", "scale_fleet", "stack_fleets", "synthetic_fleet",
    "POLICY_NAMES", "adaptive_allocation", "predictive_adaptive",
    "round_robin", "static_equal", "throughput_greedy", "water_filling",
    "register_policy", "policy_names", "policy_id", "get_policy", "dispatch",
    "policy_stack", "policy_switch", "ObjectiveWeights", "step_objective",
    "POLICY_IDS",
    "SimConfig", "SimSummary", "SimTrace", "run_policy", "simulate",
    "simulate_core", "simulate_stream_core", "summarize", "trace_metrics",
    "MetricAccum", "accumulate_metrics", "finalize_metrics",
    "init_metric_accum", "workload", "METRIC_NAMES",
    "Scenario", "SweepResult", "SweepSummary", "fleet_scenario_library",
    "scenario_library", "sweep", "sweep_fleets",
    "routing", "Workflow", "coordinator_star", "hierarchical",
    "independent_workflow", "pad_workflow", "pipeline_chain",
    "stack_workflows", "synthetic_workflow", "sweep_workflows",
    "workflow_scenario_library",
    "capacity", "CapacityConfig", "CapacityState", "billing_cost",
    "capacity_config", "capacity_policy_id", "capacity_policy_names",
    "check_capacity", "register_capacity_policy", "stack_capacities",
    "sweep_capacity", "capacity_scenario_library",
]


def __getattr__(attr: str):
    # Live views over the registry — import-time snapshots would go stale
    # the moment a policy is registered after package import.
    if attr == "POLICY_NAMES":
        return policy_names()
    if attr == "POLICY_IDS":
        return {name: i for i, name in enumerate(policy_names())}
    raise AttributeError(f"module {__name__!r} has no attribute {attr!r}")
