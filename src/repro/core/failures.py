"""Failure injection: revocation, agent outages, and request deadlines.

Mirrors the ``workload.py`` / ``capacity.py`` idiom: a single registered
pytree (``FailureSpec``) whose array leaves flow through jit/vmap, a
builder + eager validator + field-wise stacker, and a scenario library.
Unlike the allocation/capacity registries there is **no** ``lax.switch``
dispatch here — the injectors *compose* (a chaos scenario typically runs
revocation and deadlines at once), so each injector is gated by its own
knobs and all-zero knobs disable it exactly.

Three injectors:

* **instance revocation** — a Markov-modulated on/off process (the same
  two-state recurrence as the ``bursty`` MMPP workload generator) whose
  "on" state claws back ``revoke_frac`` of the warm capacity mid-step:
  the revoked share of in-service work drains back into the agent
  queues, and under an elastic capacity policy the revoked instances are
  removed from ``CapacityState.warm`` so the autoscaler must re-provision
  them through the cold-start pipeline.
* **agent failure/recovery** — transient flips of an agent's effective
  ``fleet.active`` gate (its own MMPP chain, plus an optional scheduled
  outage window for hand-computable tests).  Queues are preserved across
  the outage; arrivals keep accumulating.
* **request deadlines** — fluid-limit deadline/retry accounting: backlog
  whose projected sojourn exceeds ``deadline_s`` expires; expired mass is
  retried (re-entering the queue, up to ``retry_budget`` attempts) or
  dropped once the budget is exhausted.

RNG is counter-based and shared with the numpy oracle: step ``t`` draws
``u = uniform(fold_in(fold_in(key, t), slot))`` so both implementations
see identical chains (the oracle calls :func:`failure_uniforms` too).

Env hatch: ``REPRO_FAILURES=0`` disables failure injection at the eager
entry points (``simulate`` / ``sweep*`` / ``FleetEngine``) — a kill
switch for A/B-ing a chaos config without editing call sites.
"""
from __future__ import annotations

import os
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

# Retry classes tracked per agent: class 0 is first-attempt mass, classes
# 1..RETRY_CLASSES-1 are mass on its k-th retry.  ``retry_budget`` is
# clamped to RETRY_CLASSES - 1 so the class array stays statically sized.
RETRY_CLASSES = 4

FAILURE_ENV = "REPRO_FAILURES"

# fold_in slots for the per-step uniforms (shared with the numpy oracle).
_SLOT_REVOKE = 0
_SLOT_DOWN = 1


class FailureSpec:
    """Chaos-scenario description; registered pytree.

    Array leaves (all scalars unless noted, so one spec broadcasts over
    any (policy × agent) batch; stacked specs add a leading axis):

    * ``revoke_p_enter`` / ``revoke_p_exit`` — MMPP transition
      probabilities of the revocation chain (enter/leave the revoking
      state per step).  Both zero ⇒ injector off.
    * ``revoke_frac`` — fraction of warm capacity yanked while the chain
      is on (∈ [0, 1]).
    * ``fail_p_enter`` / ``fail_p_exit`` — per-agent outage chain
      probabilities.  Both zero ⇒ no stochastic outages.
    * ``outage_start`` / ``outage_len`` / ``outage_agent`` — scheduled
      deterministic outage window for one agent (len 0 ⇒ off); composes
      with the stochastic chain.
    * ``deadline_s`` — per-request sojourn deadline in seconds
      (scalar or (N,); ≤ 0 ⇒ deadlines off).
    * ``retry_budget`` — retry attempts before expired mass is dropped
      (clamped to ``RETRY_CLASSES - 1``).
    * ``key_data`` — (2,) uint32 raw PRNG key for the chains.

    ``name`` is static aux data (cosmetic; excluded from the treedef
    hash via equality on the leaf structure only, like ``WorkloadSpec``).
    """

    __slots__ = ("name", "revoke_p_enter", "revoke_p_exit", "revoke_frac",
                 "fail_p_enter", "fail_p_exit", "outage_start", "outage_len",
                 "outage_agent", "deadline_s", "retry_budget", "key_data")

    _LEAVES = ("revoke_p_enter", "revoke_p_exit", "revoke_frac",
               "fail_p_enter", "fail_p_exit", "outage_start", "outage_len",
               "outage_agent", "deadline_s", "retry_budget", "key_data")

    def __init__(self, name, revoke_p_enter, revoke_p_exit, revoke_frac,
                 fail_p_enter, fail_p_exit, outage_start, outage_len,
                 outage_agent, deadline_s, retry_budget, key_data):
        self.name = name
        self.revoke_p_enter = revoke_p_enter
        self.revoke_p_exit = revoke_p_exit
        self.revoke_frac = revoke_frac
        self.fail_p_enter = fail_p_enter
        self.fail_p_exit = fail_p_exit
        self.outage_start = outage_start
        self.outage_len = outage_len
        self.outage_agent = outage_agent
        self.deadline_s = deadline_s
        self.retry_budget = retry_budget
        self.key_data = key_data

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self._LEAVES), self.name

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(aux, *leaves)

    @property
    def batched(self) -> bool:
        return jnp.ndim(self.revoke_frac) > 0


jax.tree_util.register_pytree_node(
    FailureSpec, FailureSpec.tree_flatten, FailureSpec.tree_unflatten
)


def failure_spec(
    name: str = "custom",
    *,
    revoke_p_enter: float = 0.0,
    revoke_p_exit: float = 1.0,
    revoke_frac: float = 0.0,
    fail_p_enter: float = 0.0,
    fail_p_exit: float = 1.0,
    outage_start: int = 0,
    outage_len: int = 0,
    outage_agent: int = 0,
    deadline_s: float | Sequence[float] = 0.0,
    retry_budget: int = 0,
    seed: int = 0,
) -> FailureSpec:
    """Build a validated :class:`FailureSpec` (all injectors default off)."""
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    spec = FailureSpec(
        name=name,
        revoke_p_enter=f32(revoke_p_enter),
        revoke_p_exit=f32(revoke_p_exit),
        revoke_frac=f32(revoke_frac),
        fail_p_enter=f32(fail_p_enter),
        fail_p_exit=f32(fail_p_exit),
        outage_start=f32(outage_start),
        outage_len=f32(outage_len),
        outage_agent=f32(outage_agent),
        deadline_s=f32(deadline_s),
        retry_budget=f32(retry_budget),
        key_data=jax.random.key_data(jax.random.key(seed)),
    )
    check_failures(spec)
    return spec


def check_failures(spec: FailureSpec) -> None:
    """Eager validation; accepts batched (stacked) leaves."""
    import numpy as np

    def arr(x):
        return np.asarray(x, np.float64)

    for f in ("revoke_p_enter", "revoke_p_exit", "fail_p_enter",
              "fail_p_exit"):
        v = arr(getattr(spec, f))
        if ((v < 0) | (v > 1)).any():
            raise ValueError(f"failures.{f} must lie in [0, 1], got {v}")
    rf = arr(spec.revoke_frac)
    if ((rf < 0) | (rf > 1)).any():
        raise ValueError(f"failures.revoke_frac must lie in [0, 1], got {rf}")
    rb = arr(spec.retry_budget)
    if (rb < 0).any() or (rb > RETRY_CLASSES - 1).any():
        raise ValueError(
            f"failures.retry_budget must lie in [0, {RETRY_CLASSES - 1}] "
            f"(RETRY_CLASSES={RETRY_CLASSES}), got {rb}"
        )
    if (arr(spec.outage_len) < 0).any():
        raise ValueError("failures.outage_len must be >= 0")


def stack_failures(specs: Sequence[FailureSpec]) -> FailureSpec:
    """Field-wise stack for the vmapped chaos axis (leading axis = spec)."""
    if not specs:
        raise ValueError("stack_failures needs at least one spec")
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"failure scenario names must be unique, got {names}")
    # deadline_s may be scalar or (N,) — broadcast to a common shape first.
    dshape = jnp.broadcast_shapes(*(jnp.shape(s.deadline_s) for s in specs))
    leaves = {}
    for f in FailureSpec._LEAVES:
        vals = [getattr(s, f) for s in specs]
        if f == "deadline_s":
            vals = [jnp.broadcast_to(v, dshape) for v in vals]
        leaves[f] = jnp.stack(vals)
    return FailureSpec(name=tuple(names), **leaves)


def failure_names() -> tuple[str, ...]:
    """The injector families composed by this module (introspection)."""
    return ("revocation", "agent_outage", "deadline")


def failures_env_enabled() -> bool:
    return os.environ.get(FAILURE_ENV, "1") not in ("0", "false", "off")


def resolve_failures(failures: FailureSpec | None) -> FailureSpec | None:
    """Apply the ``REPRO_FAILURES`` kill switch at eager entry points."""
    if failures is not None and not failures_env_enabled():
        return None
    return failures


def failure_scenario_library(seed: int = 0) -> tuple[FailureSpec, ...]:
    """Canonical chaos scenarios for the sweep axis / benchmarks."""
    return (
        failure_spec("none", seed=seed),
        failure_spec("revoke_mild", revoke_p_enter=0.05, revoke_p_exit=0.5,
                     revoke_frac=0.5, seed=seed),
        failure_spec("revoke_harsh", revoke_p_enter=0.2, revoke_p_exit=0.3,
                     revoke_frac=0.9, seed=seed),
        failure_spec("agent_flaky", fail_p_enter=0.05, fail_p_exit=0.4,
                     seed=seed),
        failure_spec("deadline_tight", deadline_s=2.0, retry_budget=1,
                     seed=seed),
        failure_spec("chaos", revoke_p_enter=0.1, revoke_p_exit=0.4,
                     revoke_frac=0.7, fail_p_enter=0.03, fail_p_exit=0.5,
                     deadline_s=3.0, retry_budget=2, seed=seed),
    )


# ---------------------------------------------------------------------------
# Per-step chain machinery (shared by the JAX kernels and the numpy oracle)
# ---------------------------------------------------------------------------

class FailureState(NamedTuple):
    """Failure-chain scan-carry state (auto-pytree).

    Memory is O(P·N) per cell: ``retry_q`` dominates with
    (RETRY_CLASSES-1, N) per policy row.
    """
    rev_on: jnp.ndarray       # ()      revocation chain on/off
    down: jnp.ndarray         # (N,)    agent outage chains
    fail_prev: jnp.ndarray    # ()      failure was active last step
    recovering: jnp.ndarray   # (...,)  draining post-outage backlog
    q_mark: jnp.ndarray       # (...,)  pre-outage backlog watermark
    retry_q: jnp.ndarray      # (..., RETRY_CLASSES-1, N) retried mass


def init_failure_state(num_agents: int, batch_shape: tuple = ()) -> FailureState:
    z = jnp.zeros(batch_shape, jnp.float32)
    return FailureState(
        rev_on=jnp.zeros((), jnp.float32),
        down=jnp.zeros((num_agents,), jnp.float32),
        fail_prev=jnp.zeros((), jnp.float32),
        recovering=z,
        q_mark=z,
        retry_q=jnp.zeros(batch_shape + (RETRY_CLASSES - 1, num_agents),
                          jnp.float32),
    )


def failure_uniforms(spec: FailureSpec, t, num_agents: int):
    """The step-``t`` uniforms, counter-based: (u_rev (), u_down (N,)).

    Pure in ``t`` — same (spec, t) ⇒ same draws regardless of how many
    steps ran before, so the numpy oracle replays the exact chains.
    """
    key_t = jax.random.fold_in(jax.random.wrap_key_data(spec.key_data), t)
    u_rev = jax.random.uniform(jax.random.fold_in(key_t, _SLOT_REVOKE))
    u_down = jax.random.uniform(jax.random.fold_in(key_t, _SLOT_DOWN),
                                (num_agents,))
    return u_rev, u_down


def advance_failures(spec: FailureSpec, t, rev_on, down, u_rev, u_down):
    """One step of the revocation + outage chains.

    Returns ``(phi, up, rev_nxt, down_nxt)``:

    * ``phi`` () — fraction of warm capacity revoked this step
    * ``up`` (N,) — effective per-agent availability gate (1 = healthy)
    * ``rev_nxt`` / ``down_nxt`` — chain states to carry forward
      (``down_nxt`` is the *stochastic* chain only; the scheduled outage
      is recomputed from ``t`` each step and never enters the carry).

    Same two-state recurrence as the ``bursty`` MMPP generator: in-state
    stays unless ``u >= p_exit``, out-of-state enters when ``u < p_enter``.
    """
    rev_nxt = jnp.where(rev_on > 0.5, u_rev >= spec.revoke_p_exit,
                        u_rev < spec.revoke_p_enter).astype(jnp.float32)
    down_nxt = jnp.where(down > 0.5, u_down >= spec.fail_p_exit,
                         u_down < spec.fail_p_enter).astype(jnp.float32)
    phi = spec.revoke_frac * rev_nxt
    tf = jnp.asarray(t, jnp.float32)
    sched = ((tf >= spec.outage_start)
             & (tf < spec.outage_start + spec.outage_len)).astype(jnp.float32)
    col = (jnp.arange(down.shape[-1], dtype=jnp.float32)
           == spec.outage_agent).astype(jnp.float32)
    down_eff = jnp.clip(down_nxt + sched * col, 0.0, 1.0)
    return phi, 1.0 - down_eff, rev_nxt, down_nxt


def deadline_step(spec: FailureSpec, queue, lam, served, q_post, cap_eff,
                  retry_q, eps: float = 1e-9):
    """Fluid deadline/retry accounting for one step.

    Inputs are post-service quantities: ``q_post = queue + lam - served``
    is the surviving backlog and ``cap_eff`` the effective (revocation-
    scaled) service rate.  Backlog whose projected sojourn
    ``q_post / cap_eff`` exceeds ``deadline_s`` expires proportionally
    across retry classes; expired mass in classes below ``retry_budget``
    re-enters the queue one class up, the rest is dropped.

    Returns ``(new_q, new_retry_q, dropped, retried, viol)`` — all
    per-agent (..., N) except ``new_retry_q`` (..., C-1, N).  Exact mass
    balance: ``new_q = q_post - dropped``.
    """
    enabled = (spec.deadline_s > 0).astype(jnp.float32)
    # expired mass: backlog beyond what the deadline's worth of service
    # can clear.  viol doubles as the SLO-violation mass.
    expired = enabled * jnp.maximum(
        q_post - cap_eff * jnp.maximum(spec.deadline_s, 0.0), 0.0)
    # split the surviving backlog across retry classes proportionally to
    # each class's pre-service share (service is class-blind fluid).
    x = queue + lam
    f_surv = q_post / jnp.maximum(x, eps)
    m0 = jnp.maximum(x - retry_q.sum(-2), 0.0)
    m = jnp.concatenate([m0[..., None, :], retry_q], axis=-2)  # (..., C, N)
    m_post = m * f_surv[..., None, :]
    exp_frac = expired / jnp.maximum(q_post, eps)
    e = m_post * exp_frac[..., None, :]          # expired mass per class
    k = jnp.arange(RETRY_CLASSES, dtype=jnp.float32)
    budget = jnp.clip(spec.retry_budget, 0.0, RETRY_CLASSES - 1.0)
    retry_mask = (k < budget).astype(jnp.float32)[:, None]   # (C, 1)
    ret = e * retry_mask                          # re-enters, one class up
    dro = e * (1.0 - retry_mask)                  # budget exhausted: drop
    promoted = jnp.concatenate(
        [jnp.zeros_like(ret[..., :1, :]), ret[..., :-1, :]], axis=-2)
    new_m = (m_post - e) + promoted
    new_retry_q = new_m[..., 1:, :]
    dropped = dro.sum(-2)
    retried = ret.sum(-2)
    new_q = q_post - dropped
    return new_q, new_retry_q, dropped, retried, expired
