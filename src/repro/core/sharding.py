"""Device-mesh layer for the sweep grids — mesh, padding, placement.

The sweep evaluation surface is a (batch × policy × scenario) grid of
*independent* cells, which makes it embarrassingly shardable: this module
owns how that grid is laid out across devices so ``core/sweep.py`` can stay
about orchestration.

**Mesh.** ``grid_mesh()`` builds (and caches — one ``jax.make_mesh`` per
process shape, not per sweep call) a 3D mesh over all live devices with axes

    ("data", "grid", "policy")

where ``data`` carries the batched sweep axis (fleet | workflow | capacity),
``grid`` carries the scenario axis — the largest axis in every paper-style
grid, which the previous 1D layout left fully replicated on every device —
and ``policy`` optionally splits the allocation-policy stack.  By default
the policy axis is a singleton (dp=1): arrays never shard over a size-1
axis, so every pre-3D program is bit-identical to the old 2D layout.
Callers opt in with ``shard="3d"`` (near-cubic ``mesh_shape_3d`` factoring:
8 devices → 2×2×2), ``REPRO_SWEEP_POLICY_DEVICES=<dp>`` (explicit width),
or ``REPRO_SWEEP_MESH3D=1`` (global switch).  With dp>1 the streaming
kernel dispatches each device's policy *block* via one ``lax.switch`` on
``jax.lax.axis_index("policy")``; the non-divisible policy count pads with
repeats of policy row 0 (name-tuple padding — stripped host-side like every
other padded axis).  The remaining ``num_devices/dp`` factor splits
near-square with the larger factor on ``grid`` (8 devices, dp=1 → 2 × 4),
so scenario-major grids parallelize even when the batch axis is tiny.

**Divisibility.** A sharded axis must divide its mesh axis.  Instead of the
old silent whole-axis replication fallback (which forfeits *all*
parallelism — 6 fleets on 4 devices ran 4× redundantly), non-divisible axes
are **padded** to the next multiple with copies of row 0 (always-valid
cells, reusing the ``active``-mask idiom of never letting filler produce
NaNs) and the padded rows are stripped on the host side — metrics are
identical to the unpadded grid (``tests/test_sharding.py``).

**Escape hatch.** ``REPRO_SWEEP_SHARD=0`` in the environment forces the
single-device (unsharded) path everywhere, whatever the device count — the
documented debugging switch when a mesh-related failure needs to be
isolated from the grid math.

**Host-device forcing.** On CPU hosts the multi-device path is exercised by
forcing XLA to expose fake host devices (``--xla_force_host_platform_
device_count=N`` — the XLA-flag-dictionary idiom of the serving stacks this
repo's SNIPPETS reference).  ``host_device_env`` builds a subprocess
environment with N forced devices (how the scaling benchmark and the
sharding tests spawn 1/2/4/8-device workers); ``force_host_device_count``
sets the flag in-process and refuses to run once the backend is already
initialized, because the flag is read exactly once.
"""
from __future__ import annotations

import functools
import math
import os
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

DATA_AXIS = "data"     # batched sweep axis: fleet | workflow | capacity
GRID_AXIS = "grid"     # scenario axis
POLICY_AXIS = "policy"  # allocation-policy axis (the (P, N) state stack rows)

SHARD_ENV = "REPRO_SWEEP_SHARD"
MESH3D_ENV = "REPRO_SWEEP_MESH3D"          # "1": auto near-cubic policy axis
POLICY_ENV = "REPRO_SWEEP_POLICY_DEVICES"  # explicit dp override
_FORCE_FLAG = "--xla_force_host_platform_device_count"


def shard_env_enabled() -> bool:
    """False iff ``REPRO_SWEEP_SHARD=0`` (or ``false``/``off``) is set."""
    return os.environ.get(SHARD_ENV, "").lower() not in ("0", "false", "off")


def should_shard(flag: bool | None = None) -> bool:
    """Resolve one sweep call's sharding decision.

    ``flag=False`` always wins; the ``REPRO_SWEEP_SHARD=0`` escape hatch
    wins next; otherwise shard exactly when more than one device is live
    (on a single device the sharded and unsharded programs are the same
    placement, and routing through the plain jit keeps single-device
    results bit-identical by construction).
    """
    if flag is False:
        return False
    if not shard_env_enabled():
        return False
    return jax.device_count() > 1


def mesh_shape(num_devices: int) -> tuple[int, int]:
    """Factor ``num_devices`` into (data, grid) mesh dims, near-square with
    the larger factor on ``grid`` — the scenario axis dominates paper-style
    grids, so it gets the wider slice of the machine."""
    n = int(num_devices)
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    dd = max(k for k in range(1, math.isqrt(n) + 1) if n % k == 0)
    return dd, n // dd


def mesh_shape_3d(num_devices: int) -> tuple[int, int, int]:
    """Factor ``num_devices`` into (data, grid, policy) mesh dims,
    near-cubic: the policy axis takes the largest divisor whose cube fits
    (8 → 2×2×2, 64 → 4×4×4), the remainder splits near-square with the
    larger factor on ``grid`` exactly as in the 2D layout.  Primes land
    entirely on ``grid`` (7 → 1×7×1) — the policy axis degrades to
    unsharded rather than starving the scenario axis."""
    n = int(num_devices)
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    dp = max(k for k in range(1, n + 1) if n % k == 0 and k ** 3 <= n)
    dd, dg = mesh_shape(n // dp)
    return dd, dg, dp


@functools.lru_cache(maxsize=None)
def _cached_mesh(dd: int, dg: int, dp: int) -> Mesh:
    return jax.make_mesh((dd, dg, dp), (DATA_AXIS, GRID_AXIS, POLICY_AXIS))


def grid_mesh(
    num_devices: int | None = None, policy_devices: int = 1
) -> Mesh:
    """The cached ``("data", "grid", "policy")`` sweep mesh over all live
    devices.

    ``policy_devices`` (dp) is the policy-axis width; the remaining
    ``num_devices / dp`` factor splits near-square over (data, grid) as
    before.  The default ``dp=1`` is the 2D layout with a singleton third
    axis — arrays never shard over a size-1 axis, so every pre-3D program
    is unchanged.  The mesh is built once per shape and cached for the life
    of the process — the device topology cannot change after backend
    initialization, and ``jax.make_mesh`` is too expensive for a per-sweep
    rebuild.
    """
    n = jax.device_count() if num_devices is None else int(num_devices)
    dp = int(policy_devices)
    if dp < 1 or n % dp:
        raise ValueError(
            f"policy_devices={dp} must divide the device count {n}"
        )
    dd, dg = mesh_shape(n // dp)
    return _cached_mesh(dd, dg, dp)


def policy_mesh_devices(flag=None) -> int:
    """Resolve one sweep call's policy-axis device count (dp).

    ``dp=1`` — the 2D layout — unless the caller opts in: ``shard="3d"``
    requests the near-cubic ``mesh_shape_3d`` factoring, the
    ``REPRO_SWEEP_POLICY_DEVICES`` env var pins an explicit dp, and
    ``REPRO_SWEEP_MESH3D=1`` turns the near-cubic factoring on globally.
    Whenever sharding itself is off (``should_shard``), dp is 1.
    """
    if not should_shard(flag):
        return 1
    n = jax.device_count()
    env_dp = os.environ.get(POLICY_ENV, "")
    if env_dp:
        dp = int(env_dp)
        if dp < 1 or n % dp:
            raise ValueError(
                f"{POLICY_ENV}={dp} must divide the device count {n}"
            )
        return dp
    if flag == "3d" or os.environ.get(MESH3D_ENV, "").lower() in ("1", "true", "on"):
        return mesh_shape_3d(n)[2]
    return 1


def pad_axis(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    """Pad ``x`` along ``axis`` up to the next multiple of ``multiple`` by
    repeating the slice at index 0.

    Repeating a *real* row (rather than zeros) keeps every padded cell a
    well-posed simulation — no degenerate fleets, no NaN risk anywhere in
    the padded block — mirroring how ``pad_fleet`` keeps padded agent slots
    inert-but-valid.  Callers strip the rows host-side after the grid runs.
    """
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, 1)
    filler_shape = x.shape[:axis] + (pad,) + x.shape[axis + 1:]
    filler = jnp.broadcast_to(x[tuple(idx)], filler_shape)
    return jnp.concatenate([x, filler], axis=axis)


def pad_tree_axis(tree: Any, axis: int, multiple: int) -> Any:
    """``pad_axis`` over every leaf of a stacked pytree (Fleet / Workflow /
    CapacityConfig batches — static aux data like names passes through)."""
    return jax.tree_util.tree_map(lambda x: pad_axis(x, axis, multiple), tree)


def grid_specs(
    batch_axis: str | None, policy: bool = False
) -> tuple[tuple, PartitionSpec]:
    """(in_specs, out_spec) for one sharded streaming grid call.

    ``in_specs`` covers ``(arrivals, fleet, workflow, capacity, wspec,
    fspec)`` — pytree *prefixes*, so one spec serves every leaf of a
    stacked pytree.  ``wspec`` (a stacked ``WorkloadSpec``, the in-scan
    synthesis twin of the arrivals tensor) always shards exactly like
    arrivals: its leaves carry the same leading scenario/batch axes, just
    without the (S,) horizon axis, which the arrivals prefix specs never
    constrain anyway.  ``fspec`` (a ``FailureSpec``) is replicated except
    under ``batch_axis="failure"``, where its stacked scenario axis shards
    over ``data`` and the (shared) workload block over ``grid`` — the
    chaos axis lays out exactly like the other batched sweep axes.  With a
    batch axis, the batch shards over ``data`` and the scenario axis over
    ``grid``; the plain ``sweep`` grid has only a scenario axis, which
    shards over the *flattened* (data × grid) plane so no device idles.
    ``out_spec`` is the shared prefix for all four kernel outputs, whose
    layout is ([batch,] policy, scenario, ·); with ``policy=True`` the
    policy dim additionally shards over the third mesh axis (the kernel
    computes only its own block of policy rows per device — inputs stay
    replicated along ``policy``, each block reads the same state).
    """
    P = PartitionSpec
    pol = POLICY_AXIS if policy else None
    if batch_axis is None:
        both = (DATA_AXIS, GRID_AXIS)
        return (P(both), P(), P(), P(), P(both), P()), P(pol, both)
    arrivals = {
        "fleet": P(DATA_AXIS, GRID_AXIS),   # (F, W, S, N): per-fleet columns
        "workflow": P(GRID_AXIS),           # (W, S, N): one shared block
        "capacity": P(GRID_AXIS),
        "failure": P(GRID_AXIS),
    }[batch_axis]
    batched = P(DATA_AXIS)
    fleet = batched if batch_axis == "fleet" else P()
    workflow = batched if batch_axis == "workflow" else P()
    capacity = batched if batch_axis == "capacity" else P()
    fspec = batched if batch_axis == "failure" else P()
    return (
        (arrivals, fleet, workflow, capacity, arrivals, fspec),
        P(DATA_AXIS, pol, GRID_AXIS),
    )


def replicated(mesh: Mesh) -> NamedSharding:
    """Every-device replication — the old 1D fallback layout, kept only as
    the benchmark baseline (``benchmarks/scaling_frontier.py`` measures the
    redundant work it burns)."""
    return NamedSharding(mesh, PartitionSpec())


# -- host-device forcing (CPU multi-device harness) --------------------------


def _strip_force_flag(flags: str) -> list[str]:
    return [f for f in flags.split() if not f.startswith(_FORCE_FLAG)]


def host_device_env(
    num_devices: int, base_env: dict | None = None
) -> dict[str, str]:
    """Environment for a subprocess worker seeing ``num_devices`` forced
    host CPU devices — the one way to measure 1/2/4/8-device scaling on a
    CPU host, since the flag is consumed at backend initialization and can
    never change inside a live process."""
    env = dict(os.environ if base_env is None else base_env)
    env["JAX_PLATFORMS"] = "cpu"
    flags = _strip_force_flag(env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = " ".join(
        flags + [f"{_FORCE_FLAG}={int(num_devices)}"]
    ).strip()
    return env


def force_host_device_count(num_devices: int) -> None:
    """Set the forced-host-device flag for *this* process.

    Only effective before jax initializes its backends; once devices exist
    the flag is dead, so this raises instead of silently doing nothing.
    """
    if _backend_initialized():
        raise RuntimeError(
            "jax backends are already initialized; "
            f"{_FORCE_FLAG} must be set before the first device query "
            "(use host_device_env + a subprocess instead)"
        )
    flags = _strip_force_flag(os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = " ".join(
        flags + [f"{_FORCE_FLAG}={int(num_devices)}"]
    ).strip()


def _backend_initialized() -> bool:
    try:
        from jax._src import xla_bridge

        return bool(xla_bridge._backends)
    except Exception:  # private API moved: assume live, the safe answer
        return True
