"""2D device-mesh layer for the sweep grids — mesh, padding, placement.

The sweep evaluation surface is a (batch × policy × scenario) grid of
*independent* cells, which makes it embarrassingly shardable: this module
owns how that grid is laid out across devices so ``core/sweep.py`` can stay
about orchestration.

**Mesh.** ``grid_mesh()`` builds (and caches — one ``jax.make_mesh`` per
process, not per sweep call) a 2D mesh over all live devices with axes

    ("data", "grid")

where ``data`` carries the batched sweep axis (fleet | workflow | capacity)
and ``grid`` carries the scenario axis — the largest axis in every
paper-style grid, which the previous 1D layout left fully replicated on
every device.  The device count is factored near-square with the larger
factor on ``grid`` (8 devices → 2 × 4), so scenario-major grids parallelize
even when the batch axis is tiny.

**Divisibility.** A sharded axis must divide its mesh axis.  Instead of the
old silent whole-axis replication fallback (which forfeits *all*
parallelism — 6 fleets on 4 devices ran 4× redundantly), non-divisible axes
are **padded** to the next multiple with copies of row 0 (always-valid
cells, reusing the ``active``-mask idiom of never letting filler produce
NaNs) and the padded rows are stripped on the host side — metrics are
identical to the unpadded grid (``tests/test_sharding.py``).

**Escape hatch.** ``REPRO_SWEEP_SHARD=0`` in the environment forces the
single-device (unsharded) path everywhere, whatever the device count — the
documented debugging switch when a mesh-related failure needs to be
isolated from the grid math.

**Host-device forcing.** On CPU hosts the multi-device path is exercised by
forcing XLA to expose fake host devices (``--xla_force_host_platform_
device_count=N`` — the XLA-flag-dictionary idiom of the serving stacks this
repo's SNIPPETS reference).  ``host_device_env`` builds a subprocess
environment with N forced devices (how the scaling benchmark and the
sharding tests spawn 1/2/4/8-device workers); ``force_host_device_count``
sets the flag in-process and refuses to run once the backend is already
initialized, because the flag is read exactly once.
"""
from __future__ import annotations

import functools
import math
import os
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

DATA_AXIS = "data"   # batched sweep axis: fleet | workflow | capacity
GRID_AXIS = "grid"   # scenario axis

SHARD_ENV = "REPRO_SWEEP_SHARD"
_FORCE_FLAG = "--xla_force_host_platform_device_count"


def shard_env_enabled() -> bool:
    """False iff ``REPRO_SWEEP_SHARD=0`` (or ``false``/``off``) is set."""
    return os.environ.get(SHARD_ENV, "").lower() not in ("0", "false", "off")


def should_shard(flag: bool | None = None) -> bool:
    """Resolve one sweep call's sharding decision.

    ``flag=False`` always wins; the ``REPRO_SWEEP_SHARD=0`` escape hatch
    wins next; otherwise shard exactly when more than one device is live
    (on a single device the sharded and unsharded programs are the same
    placement, and routing through the plain jit keeps single-device
    results bit-identical by construction).
    """
    if flag is False:
        return False
    if not shard_env_enabled():
        return False
    return jax.device_count() > 1


def mesh_shape(num_devices: int) -> tuple[int, int]:
    """Factor ``num_devices`` into (data, grid) mesh dims, near-square with
    the larger factor on ``grid`` — the scenario axis dominates paper-style
    grids, so it gets the wider slice of the machine."""
    n = int(num_devices)
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    dd = max(k for k in range(1, math.isqrt(n) + 1) if n % k == 0)
    return dd, n // dd


@functools.lru_cache(maxsize=None)
def _cached_mesh(dd: int, dg: int) -> Mesh:
    return jax.make_mesh((dd, dg), (DATA_AXIS, GRID_AXIS))


def grid_mesh(num_devices: int | None = None) -> Mesh:
    """The cached 2D ``("data", "grid")`` sweep mesh over all live devices.

    The mesh is built once per (data, grid) shape and cached for the life
    of the process — the device topology cannot change after backend
    initialization, and ``jax.make_mesh`` is too expensive for a per-sweep
    rebuild.
    """
    n = jax.device_count() if num_devices is None else int(num_devices)
    return _cached_mesh(*mesh_shape(n))


def pad_axis(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    """Pad ``x`` along ``axis`` up to the next multiple of ``multiple`` by
    repeating the slice at index 0.

    Repeating a *real* row (rather than zeros) keeps every padded cell a
    well-posed simulation — no degenerate fleets, no NaN risk anywhere in
    the padded block — mirroring how ``pad_fleet`` keeps padded agent slots
    inert-but-valid.  Callers strip the rows host-side after the grid runs.
    """
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, 1)
    filler_shape = x.shape[:axis] + (pad,) + x.shape[axis + 1:]
    filler = jnp.broadcast_to(x[tuple(idx)], filler_shape)
    return jnp.concatenate([x, filler], axis=axis)


def pad_tree_axis(tree: Any, axis: int, multiple: int) -> Any:
    """``pad_axis`` over every leaf of a stacked pytree (Fleet / Workflow /
    CapacityConfig batches — static aux data like names passes through)."""
    return jax.tree_util.tree_map(lambda x: pad_axis(x, axis, multiple), tree)


def grid_specs(batch_axis: str | None) -> tuple[tuple, PartitionSpec]:
    """(in_specs, out_spec) for one sharded streaming grid call.

    ``in_specs`` covers ``(arrivals, fleet, workflow, capacity)`` — pytree
    *prefixes*, so one spec serves every leaf of a stacked pytree.  With a
    batch axis, the batch shards over ``data`` and the scenario axis over
    ``grid``; the plain ``sweep`` grid has only a scenario axis, which
    shards over the *flattened* mesh (both axes) so no device idles.
    ``out_spec`` is the shared prefix for all four kernel outputs, whose
    layout is ([batch,] policy, scenario, ·).
    """
    P = PartitionSpec
    if batch_axis is None:
        both = (DATA_AXIS, GRID_AXIS)
        return (P(both), P(), P(), P()), P(None, both)
    arrivals = {
        "fleet": P(DATA_AXIS, GRID_AXIS),   # (F, W, S, N): per-fleet columns
        "workflow": P(GRID_AXIS),           # (W, S, N): one shared block
        "capacity": P(GRID_AXIS),
    }[batch_axis]
    batched = P(DATA_AXIS)
    fleet = batched if batch_axis == "fleet" else P()
    workflow = batched if batch_axis == "workflow" else P()
    capacity = batched if batch_axis == "capacity" else P()
    return (arrivals, fleet, workflow, capacity), P(DATA_AXIS, None, GRID_AXIS)


def replicated(mesh: Mesh) -> NamedSharding:
    """Every-device replication — the old 1D fallback layout, kept only as
    the benchmark baseline (``benchmarks/scaling_frontier.py`` measures the
    redundant work it burns)."""
    return NamedSharding(mesh, PartitionSpec())


# -- host-device forcing (CPU multi-device harness) --------------------------


def _strip_force_flag(flags: str) -> list[str]:
    return [f for f in flags.split() if not f.startswith(_FORCE_FLAG)]


def host_device_env(
    num_devices: int, base_env: dict | None = None
) -> dict[str, str]:
    """Environment for a subprocess worker seeing ``num_devices`` forced
    host CPU devices — the one way to measure 1/2/4/8-device scaling on a
    CPU host, since the flag is consumed at backend initialization and can
    never change inside a live process."""
    env = dict(os.environ if base_env is None else base_env)
    env["JAX_PLATFORMS"] = "cpu"
    flags = _strip_force_flag(env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = " ".join(
        flags + [f"{_FORCE_FLAG}={int(num_devices)}"]
    ).strip()
    return env


def force_host_device_count(num_devices: int) -> None:
    """Set the forced-host-device flag for *this* process.

    Only effective before jax initializes its backends; once devices exist
    the flag is dead, so this raises instead of silently doing nothing.
    """
    if _backend_initialized():
        raise RuntimeError(
            "jax backends are already initialized; "
            f"{_FORCE_FLAG} must be set before the first device query "
            "(use host_device_env + a subprocess instead)"
        )
    flags = _strip_force_flag(os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = " ".join(
        flags + [f"{_FORCE_FLAG}={int(num_devices)}"]
    ).strip()


def _backend_initialized() -> bool:
    try:
        from jax._src import xla_bridge

        return bool(xla_bridge._backends)
    except Exception:  # private API moved: assume live, the safe answer
        return True
