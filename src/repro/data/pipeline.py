"""Deterministic synthetic token pipeline.

Generates a reproducible "language" (Zipfian unigrams with a Markov
low-rank structure so the loss actually decreases) without external data.
Shard-aware: each (data-parallel) host slice can be produced independently
from the (seed, step, shard) triple.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    num_states: int = 16   # Markov states -> learnable structure


class SyntheticTokens:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        base = ranks ** (-cfg.zipf_a)
        # Per-state token distributions: Zipf re-permuted per Markov state.
        self._state_dists = []
        for _ in range(cfg.num_states):
            p = base[rng.permutation(v)]
            self._state_dists.append(p / p.sum())
        self._trans = rng.dirichlet(np.ones(cfg.num_states) * 0.5, size=cfg.num_states)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        states = np.zeros((b, s), np.int64)
        states[:, 0] = rng.integers(0, cfg.num_states, b)
        for t in range(1, s):
            u = rng.random(b)
            cum = np.cumsum(self._trans[states[:, t - 1]], axis=1)
            states[:, t] = (u[:, None] < cum).argmax(1)
        tokens = np.zeros((b, s), np.int32)
        for st in range(cfg.num_states):
            m = states == st
            n = int(m.sum())
            if n:
                tokens[m] = rng.choice(cfg.vocab_size, size=n, p=self._state_dists[st])
        labels = np.concatenate([tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
