"""AdamW + cosine schedule + global-norm clipping, from scratch in JAX.

Optimizer state (m, v) is kept in float32 regardless of parameter dtype and
inherits each parameter's sharding (declared via the same ParamDecl tree),
so the dry-run sees the true per-device optimizer memory.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(step: jnp.ndarray, cfg: OptimizerConfig) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    frac = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.copy, zeros), "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(abstract_params):
    z = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params
    )
    return {"m": z, "v": z, "step": jax.ShapeDtypeStruct((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig):
    """One AdamW step with global-norm clipping; returns (params, state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(step, cfg)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [n[0] for n in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [n[1] for n in new])
    new_v = jax.tree_util.tree_unflatten(treedef, [n[2] for n in new])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
