"""The jitted training step: loss -> grad -> clip -> AdamW."""
from __future__ import annotations

from typing import Callable

import jax

from repro.models.model import ModelApi
from repro.training.optimizer import OptimizerConfig, adamw_update


def build_train_step(api: ModelApi, opt_cfg: OptimizerConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(api.train_loss, has_aux=True)(
            params, batch
        )
        params, opt_state, stats = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **stats}

    return train_step
