"""Backend dispatch for attention: Pallas TPU kernel vs pure-jnp reference.

The Pallas kernels are written for the TPU memory hierarchy (HBM->VMEM
streaming, MXU-aligned tiles) and validated on CPU in ``interpret=True``
mode by the kernel tests.  Production model code calls these wrappers; on a
CPU backend (this container, smoke tests, the multi-pod dry-run) they fall
back to the reference, which is bit-for-bit the oracle the kernels are
tested against.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.attention import ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset", "impl"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0, impl=None):
    """(B,S_q,H,D) x (B,S_kv,KV,D)^2 -> (B,S_q,H,D)."""
    impl = impl or _default_impl()
    if impl == "ref":
        s_q, s_kv = q.shape[1], k.shape[1]
        if (causal and window > 0 and q_offset == 0 and s_q == s_kv
                and s_q % window == 0 and s_q >= 2 * window):
            # Banded SWA: 2W work per query instead of S (§Perf pair 5).
            return ref.mha_banded(q, k, v, window=window)
        return ref.mha(q, k, v, causal=causal, window=window, q_offset=q_offset)
    from repro.kernels.attention import flash_attention as fa

    interpret = jax.default_backend() != "tpu"
    return fa.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("window", "impl"))
def decode_attention(q, k_cache, v_cache, cache_len, *, window=0, impl=None):
    """(B,H,D) x (B,S_max,KV,D)^2 -> (B,H,D), masked to `cache_len` entries."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.decode_gqa(q, k_cache, v_cache, cache_len, window=window)
    from repro.kernels.attention import decode_attention as da

    interpret = jax.default_backend() != "tpu"
    return da.decode_attention(
        q, k_cache, v_cache, cache_len, window=window, interpret=interpret
    )
