"""Pure-jnp oracle for the attention kernels.

Shapes (GQA throughout):
  q:      (B, S_q, H, D)
  k, v:   (B, S_kv, KV, D)   with H % KV == 0
Decode:
  q:      (B, H, D)          one new token
  cache:  (B, S_max, KV, D)

``window > 0`` = sliding-window causal attention (Mixtral / local attention
in RecurrentGemma).  ``causal=False, window=0`` = bidirectional (encoder) or
cross attention.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def _expand_kv(x: jnp.ndarray, num_q_heads: int) -> jnp.ndarray:
    """(B, S, KV, D) -> (B, S, H, D) by repeating each KV head."""
    kv = x.shape[2]
    if kv == num_q_heads:
        return x
    assert num_q_heads % kv == 0, (num_q_heads, kv)
    return jnp.repeat(x, num_q_heads // kv, axis=2)


def attention_mask(
    s_q: int,
    s_kv: int,
    *,
    causal: bool,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    """(S_q, S_kv) boolean mask; True = attend."""
    q_pos = jnp.arange(s_q)[:, None] + q_offset
    k_pos = jnp.arange(s_kv)[None, :]
    mask = jnp.ones((s_q, s_kv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    return mask


def mha(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Reference multi-head (GQA) attention, fp32 softmax.

    GQA is expressed as a grouped einsum (q reshaped to (B,S,KV,G,D)) rather
    than repeating K/V: repetition materializes a group-times larger KV
    tensor, which under SPMD forces the partitioner into full-cache copies
    (§Perf iteration log).
    """
    b, s_q, h, d = q.shape
    s_kv, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s_q, kv, g, d)
    logits = jnp.einsum("bqngd,bknd->bngqk", qg, k).astype(jnp.float32)
    logits *= 1.0 / jnp.sqrt(d).astype(jnp.float32)
    mask = attention_mask(s_q, s_kv, causal=causal, window=window, q_offset=q_offset)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bngqk,bknd->bqngd", probs.astype(v.dtype), v)
    return out.reshape(b, s_q, h, d).astype(q.dtype)


def mha_banded(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    window: int,
) -> jnp.ndarray:
    """Sliding-window causal attention computed BANDED: with block size ==
    window, query block b attends only kv blocks (b-1, b), so compute is
    2·W per query instead of S — a ~S/(2W) FLOP/byte reduction at long
    prefill (§Perf pair 5).  Exact match of ``mha(causal=True, window=W)``
    when S % W == 0 (asserted by the caller/ops dispatch).
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    w = window
    assert s % w == 0 and s >= w, (s, w)
    nb = s // w

    qg = q.reshape(b, nb, w, kv, g, d)
    kb = k.reshape(b, nb, w, kv, d)
    vb = v.reshape(b, nb, w, kv, d)
    # previous kv block per q block (block 0's "previous" is fully masked)
    kp = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vp = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)

    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    lg_cur = jnp.einsum("bcqngd,bcknd->bcngqk", qg, kb).astype(jnp.float32) * scale
    lg_prev = jnp.einsum("bcqngd,bcknd->bcngqk", qg, kp).astype(jnp.float32) * scale

    qi = jnp.arange(w)[:, None]
    kj = jnp.arange(w)[None, :]
    # current block: causal (and k > q - w holds automatically: same block)
    mask_cur = kj <= qi
    # previous block: k_pos = kj + (c-1)w, q_pos = qi + cw -> k > q - w <=> kj > qi
    mask_prev = kj > qi
    lg_cur = jnp.where(mask_cur, lg_cur, NEG_INF)
    lg_prev = jnp.where(mask_prev, lg_prev, NEG_INF)
    block0 = jnp.arange(nb)[None, :, None, None, None, None] == 0
    lg_prev = jnp.where(block0, NEG_INF, lg_prev)

    lg = jnp.concatenate([lg_prev, lg_cur], axis=-1)          # (B,C,N,G,W,2W)
    probs = jnp.exp(lg - lg.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    p_prev, p_cur = jnp.split(probs.astype(v.dtype), 2, axis=-1)
    out = jnp.einsum("bcngqk,bcknd->bcqngd", p_cur, vb)
    out = out + jnp.einsum("bcngqk,bcknd->bcqngd", p_prev, vp)
    return out.reshape(b, s, h, d).astype(q.dtype)


def decode_gqa(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,
    *,
    window: int = 0,
) -> jnp.ndarray:
    """One-token decode attention against a (possibly rolling) KV cache.

    q: (B, H, D); caches: (B, S_max, KV, D); cache_len: () or (B,) number of
    valid entries.  For rolling (sliding-window) caches the valid region is
    the whole buffer once cache_len >= S_max; masking uses entry validity
    only — relative order is irrelevant to softmax(QK^T)V.  No KV
    repetition (see ``mha``).
    """
    b, h, d = q.shape
    s_max, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, d)
    logits = jnp.einsum("bngd,bknd->bngk", qg, k_cache).astype(jnp.float32)
    logits *= 1.0 / jnp.sqrt(d).astype(jnp.float32)
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        cache_len = jnp.broadcast_to(cache_len, (b,))
    pos = jnp.arange(s_max)[None, :]
    valid = pos < cache_len[:, None]
    if window > 0:
        valid &= pos >= (cache_len[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bngk,bknd->bngd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, h, d).astype(q.dtype)
