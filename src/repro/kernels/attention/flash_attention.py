"""Flash attention for TPU (Pallas): prefill / training hot path.

TPU-native adaptation (not a CUDA port): Q tiles live in VMEM while K/V
stream HBM->VMEM block by block along the innermost grid dimension; the
online-softmax accumulators (acc, m, l) persist in VMEM scratch across the
K/V grid steps, and all matmul tiles are MXU-aligned (block sizes are
multiples of 128 where shapes allow).  GQA is expressed in the K/V
BlockSpec index maps (q-head -> kv-head // group), so no KV repetition is
ever materialized.

Validated against ``ref.mha`` in interpret mode (CPU) by
tests/test_kernels.py across shape/dtype/mask sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale, causal, window, q_offset, bq, bk, s_q, s_kv,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Skip fully-masked K/V blocks (beyond causal diagonal / window).
    q_last = qi * bq + bq - 1 + q_offset
    k_first = ki * bk
    k_last = ki * bk + bk - 1
    needed = jnp.bool_(True)
    if causal:
        needed &= k_first <= q_last
    if window > 0:
        q_first = qi * bq + q_offset
        needed &= k_last > q_first - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (BQ, BK)
        mask = k_pos < s_kv
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)               # (BQ, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal=True, window=0, q_offset=0,
    block_q=128, block_k=128, interpret=False,
):
    """q (B,S_q,H,D), k/v (B,S_kv,KV,D) -> (B,S_q,H,D)."""
    b, s_q, h, d = q.shape
    s_kv, kv = k.shape[1], k.shape[2]
    group = h // kv
    bq = min(block_q, s_q)
    bk = min(block_k, s_kv)

    qt = jnp.swapaxes(q, 1, 2)                        # (B,H,Sq,D)
    kt = jnp.swapaxes(k, 1, 2)                        # (B,KV,Skv,D)
    vt = jnp.swapaxes(v, 1, 2)

    pad_q = (-s_q) % bq
    pad_k = (-s_kv) % bk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[2] // bq
    nk = kt.shape[2] // bk

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=1.0 / (d ** 0.5), causal=causal, window=window,
            q_offset=q_offset, bq=bq, bk=bk, s_q=s_q, s_kv=s_kv,
        ),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    if pad_q:
        out = out[:, :, :s_q]
    return jnp.swapaxes(out, 1, 2)
