"""GQA decode attention for TPU (Pallas): one query token vs a large KV
cache.  This op is memory-bound (arithmetic intensity ~ O(group)); the
kernel streams the KV cache HBM->VMEM in ``block_k``-sized slabs along the
innermost grid dimension and keeps the whole q-head *group* resident, so
each cache byte is read exactly once per kv-head regardless of group size.

Masking supports both plain caches (valid = pos < cache_len) and rolling
sliding-window caches (cache size == window; all written slots valid).

Validated against ``ref.decode_gqa`` in interpret mode by
tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale, window, bk, s_max,
):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[0]
    pos = si * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    valid = pos < jnp.minimum(cache_len, s_max)
    if window > 0:
        valid &= pos >= cache_len - window

    @pl.when((si * bk) < jnp.minimum(cache_len, s_max))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (G, BK)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(
    q, k_cache, v_cache, cache_len, *, window=0, block_k=512, interpret=False,
):
    """q (B,H,D) x caches (B,S_max,KV,D) -> (B,H,D)."""
    b, h, d = q.shape
    s_max, kv = k_cache.shape[1], k_cache.shape[2]
    group = h // kv
    bk = min(block_k, s_max)

    qt = q.reshape(b, kv, group, d)                   # (B,KV,G,D)
    kt = jnp.swapaxes(k_cache, 1, 2)                  # (B,KV,S,D)
    vt = jnp.swapaxes(v_cache, 1, 2)
    pad = (-s_max) % bk
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    ns = kt.shape[2] // bk

    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim == 0:
        cache_len = jnp.broadcast_to(cache_len, (b,))

    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / (d ** 0.5), window=window, bk=bk, s_max=s_max),
        grid=(b, kv, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, kv_, si: (b_,)),
            pl.BlockSpec((1, 1, group, d), lambda b_, kv_, si: (b_, kv_, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, kv_, si: (b_, kv_, si, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, kv_, si: (b_, kv_, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b_, kv_, si: (b_, kv_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, qt, kt, vt)
    return out.reshape(b, h, d)
