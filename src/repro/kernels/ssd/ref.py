"""Pure-jnp oracles for the Mamba-2 SSD (state-space duality) scan.

Per head h with state size N and head dim P, the recurrence over time is

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * (B_t outer x_t)      (P, N)
    y_t = h_t @ C_t + D * x_t

Shapes (single B/C group, as in Mamba-2 defaults):
    x:  (B, S, H, P)    dt: (B, S, H)    A, D: (H,)
    Bm, Cm: (B, S, N)

``ssd_naive`` is the sequential-scan oracle; ``ssd_chunked`` is the
quadratic-within-chunk / linear-across-chunks SSD algorithm (arXiv:2405.21060
§6) — the same decomposition the Pallas kernel tiles into VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_naive(x, dt, A, Bm, Cm, D, h0=None):
    """Sequential recurrence; returns (y, h_final)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h_init = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(hprev, inp):
        xt, dtt, bt, ct = inp                    # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(Af[None] * dtt)          # (B,H)
        upd = dtt[..., None, None] * xt[..., None] * bt[:, None, None, :]
        hnew = decay[..., None, None] * hprev + upd
        yt = jnp.einsum("bhpn,bn->bhp", hnew, ct)
        return hnew, yt

    inputs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h_init, inputs)
    y = jnp.moveaxis(ys, 0, 1) + D[None, None, :, None].astype(jnp.float32) * xf
    return y.astype(x.dtype), h_final


def _segsum(a):
    """Stable segment-sum: out[..., t, s] = sum_{r=s+1..t} a[..., r] (t >= s)."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, D, h0=None, chunk: int = 64):
    """Chunked SSD; exact (up to fp assoc.) match of ``ssd_naive``."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    if s % chunk:
        # Pad with dt=0 steps: decay exp(A*0)=1 and zero input contribution,
        # so the final state is unchanged; padded outputs are sliced off.
        pad = chunk - s % chunk
        y, hf = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0))),
            D, h0=h0, chunk=chunk,
        )
        return y[:, :s], hf
    c = s // chunk
    xf = x.astype(jnp.float32).reshape(b, c, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, c, chunk, h)
    Bf = Bm.astype(jnp.float32).reshape(b, c, chunk, n)
    Cf = Cm.astype(jnp.float32).reshape(b, c, chunk, n)
    Af = A.astype(jnp.float32)

    a = Af[None, None, None, :] * dtf                     # (B,C,Q,H)
    a_h = jnp.moveaxis(a, -1, 2)                          # (B,C,H,Q)
    a_cum = jnp.cumsum(a_h, axis=-1)                      # within-chunk cumsum
    a_tot = a_cum[..., -1]                                # (B,C,H)

    # Intra-chunk (quadratic within the chunk):
    L = jnp.exp(_segsum(a_h))                             # (B,C,H,Q,Q)
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cf, Bf)        # (B,C,Q,Q)
    gated = scores[:, :, None] * L                        # (B,C,H,Q,Q)
    y_intra = jnp.einsum("bchqs,bcsh,bcshp->bcqhp", gated, dtf, xf)

    # Chunk states: contribution of each chunk to the running state.
    decay_tail = jnp.exp(a_tot[..., None] - a_cum)        # (B,C,H,Q)
    states = jnp.einsum("bchq,bcqh,bcqhp,bcqn->bchpn", decay_tail, dtf, xf, Bf)

    # Inter-chunk recurrence over c (linear):
    h_init = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def chunk_step(hprev, inp):
        st, atot = inp                                    # (B,H,P,N), (B,H)
        hnew = jnp.exp(atot)[..., None, None] * hprev + st
        return hnew, hprev

    h_final, h_prevs = jax.lax.scan(
        chunk_step,
        h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_tot, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (B,C,H,P,N) state entering chunk

    # Inter-chunk output: decayed previous state read out by C.
    decay_in = jnp.exp(a_cum)                             # (B,C,H,Q)
    y_inter = jnp.einsum("bcqn,bchpn,bchq->bcqhp", Cf, h_prevs, decay_in)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + D[None, None, :, None].astype(jnp.float32) * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final


def ssd_decode_step(x, dt, A, Bm, Cm, D, h):
    """One-token update: x (B,H,P), dt (B,H), Bm/Cm (B,N), h (B,H,P,N)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    decay = jnp.exp(A[None].astype(jnp.float32) * dtf)
    upd = dtf[..., None, None] * xf[..., None] * Bm[:, None, None, :].astype(jnp.float32)
    hnew = decay[..., None, None] * h + upd
    y = jnp.einsum("bhpn,bn->bhp", hnew, Cm.astype(jnp.float32))
    y = y + D[None, :, None].astype(jnp.float32) * xf
    return y.astype(x.dtype), hnew
