"""Mamba-2 SSD chunked scan for TPU (Pallas).

TPU-native adaptation of the SSD algorithm (arXiv:2405.21060 §6): the
sequence is processed in chunks along the innermost grid dimension; the
(P, N) recurrent state lives in VMEM scratch and persists across chunk
steps, so HBM traffic is exactly one read of (x, dt, B, C) and one write
of y — the quadratic intra-chunk work runs on the MXU as (Q,Q) and (Q,N)
matmuls.

Grid: (batch, heads, num_chunks).  Validated against ``ref.ssd_chunked``
and ``ref.ssd_naive`` in interpret mode by tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
    h_ref,
    *, q,
):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    bm = b_ref[0].astype(jnp.float32)                  # (Q, N)
    cm = c_ref[0].astype(jnp.float32)                  # (Q, N)
    a_h = a_ref[0]                                     # scalar A for this head
    d_h = d_ref[0]

    a = a_h * dt                                       # (Q,)
    a_cum = jnp.cumsum(a)                              # within-chunk
    a_tot = a_cum[-1]

    # Intra-chunk: y_t += sum_{s<=t} exp(a_cum_t - a_cum_s) dt_s (C_t.B_s) x_s
    seg = a_cum[:, None] - a_cum[None, :]              # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (q, q), 1
    )
    gate = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (Q, Q)
    w = scores * gate * dt[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (Q, P)

    # Inter-chunk: read out the carried state.
    h = h_ref[...]                                     # (P, N)
    decay_in = jnp.exp(a_cum)[:, None]                 # (Q, 1)
    y += jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * decay_in

    # State update: h <- exp(a_tot) h + sum_s exp(a_tot - a_cum_s) dt_s x_s B_s^T
    wstate = (jnp.exp(a_tot - a_cum) * dt)[:, None]    # (Q, 1)
    upd = jax.lax.dot_general(
        x * wstate, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (P, N)
    h_ref[...] = jnp.exp(a_tot) * h + upd

    y_ref[0, :, 0, :] = (y + d_h * x).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0, 0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, D, h0=None, *, chunk=64, interpret=False):
    """x (B,S,H,P), dt (B,S,H), A/D (H,), Bm/Cm (B,S,N) -> (y, h_final).

    h0 is folded in by the wrapper (kernel state starts at zero): a nonzero
    initial state contributes C_t exp(a_cum_t) h0 per step, which equals
    running the kernel with one virtual dt=0 prefix chunk; for simplicity we
    add the h0 read-out outside the kernel (exact, used by decode restarts).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    if s % chunk:
        pad = chunk - s % chunk
        y, hf = ssd(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0))),
            D, h0=h0, chunk=chunk, interpret=interpret,
        )
        return y[:, :s], hf

    nc = s // chunk
    y, hout = pl.pallas_call(
        functools.partial(_kernel, q=chunk),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(
        x,
        dt.astype(jnp.float32),
        A.astype(jnp.float32),
        Bm, Cm,
        D.astype(jnp.float32),
    )
    if h0 is not None:
        # Exact h0 correction: y_t += C_t (prod_{r<=t} a_r) h0 per head.
        af = A.astype(jnp.float32)
        a_all = af[None, None, :] * dt.astype(jnp.float32)       # (B,S,H)
        cum = jnp.cumsum(a_all, axis=1)
        contrib = jnp.einsum(
            "bsn,bhpn->bshp", Cm.astype(jnp.float32), h0.astype(jnp.float32)
        ) * jnp.exp(cum)[..., None]
        y = (y.astype(jnp.float32) + contrib).astype(x.dtype)
        hf = hout + h0.astype(jnp.float32) * jnp.exp(cum[:, -1])[..., None, None]
        return y, hf
    return y, hout
