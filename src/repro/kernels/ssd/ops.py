"""Backend dispatch for the Mamba-2 SSD scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd import ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd(x, dt, A, Bm, Cm, D, h0=None, *, chunk=64, impl=None):
    """Chunked SSD scan; returns (y, final_state)."""
    impl = impl or _default_impl()
    if impl == "ref":
        return ref.ssd_chunked(x, dt, A, Bm, Cm, D, h0=h0, chunk=chunk)
    from repro.kernels.ssd import ssd_scan

    interpret = jax.default_backend() != "tpu"
    return ssd_scan.ssd(x, dt, A, Bm, Cm, D, h0=h0, chunk=chunk, interpret=interpret)


ssd_decode_step = jax.jit(ref.ssd_decode_step)
