"""Mixture-of-Experts FFN with top-k routing (Mixtral / Granite-MoE).

Baseline implementation is the GShard/Mesh-TF capacity-based dispatch:
tokens are routed to ``experts_per_token`` experts; each expert processes at
most ``capacity = ceil(S*k/E * capacity_factor)`` tokens per example;
overflow tokens fall through on the residual path.  Dispatch/combine are
one-hot einsums — fully dense, shardable, and the collective pattern
(all-to-all on the expert axis) is explicit to GSPMD.

A sort-based "grouped" variant (``impl='grouped'``) removes the one-hot
dispatch FLOPs (B*S*E*C*D) and is the beyond-paper optimization studied in
EXPERIMENTS.md §Perf.

Router load-balancing follows Switch Transformer: aux loss = E * Σ_e f_e·p_e.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ffn_decls
from repro.models.params import decl


def moe_decls(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": decl((d, e), ("embed", "experts")),
        "w_gate": decl((e, d, f), ("experts", "embed", "ffn")),
        "w_up": decl((e, d, f), ("experts", "embed", "ffn")),
        "w_down": decl((e, f, d), ("experts", "ffn", "embed")),
    }


def capacity(tokens_per_example: int, cfg: ModelConfig, factor: float = 1.25) -> int:
    cap = math.ceil(tokens_per_example * cfg.experts_per_token / cfg.num_experts * factor)
    return max(8, -(-cap // 8) * 8)  # pad to a multiple of 8 for tiling


def _router(x, p, cfg: ModelConfig):
    """Top-k routing probabilities; returns (weights, expert_ids, aux_loss)."""
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,E)
    top_p, top_ids = jax.lax.top_k(probs, cfg.experts_per_token)  # (B,S,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss.
    e = cfg.num_experts
    onehot = jax.nn.one_hot(top_ids, e, dtype=jnp.float32)        # (B,S,K,E)
    frac_routed = onehot.sum(2).mean((0, 1))                      # f_e
    frac_prob = probs.mean((0, 1))                                # p_e
    aux = e * jnp.sum(frac_routed * frac_prob)
    return top_p, top_ids, aux


def _expert_ffn(inp, p, cfg: ModelConfig):
    """inp: (E, B, C, D) -> (E, B, C, D); batched SwiGLU over experts."""
    gate = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", inp, p["w_gate"]))
    up = jnp.einsum("ebcd,edf->ebcf", inp, p["w_up"])
    return jnp.einsum("ebcf,efd->ebcd", gate * up, p["w_down"])


def moe_ffn(x: jnp.ndarray, p, cfg: ModelConfig, *, capacity_factor: float = 1.25):
    """x: (B, S, D) -> (out, aux_loss).  GShard capacity dispatch."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = capacity(s, cfg, capacity_factor)
    weights, ids, aux = _router(x, p, cfg)                         # (B,S,K)

    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)             # (B,S,K,E)
    # Position of each (token, k) within its expert's capacity buffer:
    # cumulative count of prior routings to the same expert across (S, K).
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                          # (B,S*K,E)
    pos = pos.reshape(b, s, k, e)
    pos_tok = jnp.take_along_axis(
        pos, ids[..., None].astype(jnp.int32), axis=-1
    )[..., 0]                                                      # (B,S,K)
    keep = pos_tok < c

    # dispatch[b,s,e,c] / combine[b,s,e,c], built per routing slot k so the
    # largest intermediate is (B,S,E,C) — never (B,S,K,E,C).
    dispatch = jnp.zeros((b, s, e, c), jnp.float32)
    combine = jnp.zeros((b, s, e, c), jnp.float32)
    for kk in range(k):
        oe = onehot[:, :, kk] * keep[:, :, kk, None]               # (B,S,E)
        oc = jax.nn.one_hot(
            jnp.minimum(pos_tok[:, :, kk], c - 1).astype(jnp.int32), c,
            dtype=jnp.float32,
        )                                                          # (B,S,C)
        piece = jnp.einsum("bse,bsc->bsec", oe, oc)
        dispatch = dispatch + piece
        combine = combine + piece * weights[:, :, kk, None, None]

    xin = x.astype(jnp.float32)
    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, xin).astype(x.dtype)
    expert_out = _expert_ffn(expert_in, p, cfg)
    out = jnp.einsum("bsec,ebcd->bsd", combine, expert_out.astype(jnp.float32))
    return out.astype(x.dtype), aux


def moe_ffn_grouped(x: jnp.ndarray, p, cfg: ModelConfig, *, capacity_factor: float = 1.25):
    """Sort-free scatter/gather MoE (beyond-paper §Perf variant).

    Replaces the (B,S,E,C) one-hot dispatch einsums with integer
    scatter/gather: O(B·S·K·D) data movement instead of O(B·S·E·C·D) MACs.
    Numerics match ``moe_ffn`` exactly (same capacity-drop rule).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = capacity(s, cfg, capacity_factor)
    weights, ids, aux = _router(x, p, cfg)

    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)
    flat = onehot.reshape(b, s * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(b, s, k, e)
    pos_tok = jnp.take_along_axis(
        pos, ids[..., None].astype(jnp.int32), axis=-1
    )[..., 0].astype(jnp.int32)                                    # (B,S,K)
    keep = pos_tok < c
    pos_safe = jnp.minimum(pos_tok, c - 1)

    # Scatter tokens into (B, E, C, D) expert buffers.  Buffers stay in the
    # model dtype: each (token, k) slot is written at most once (positions
    # within an expert are unique), so no accumulation precision is lost —
    # f32 buffers here doubled the dominant memory-roofline term (§Perf).
    buf = jnp.zeros((b, e, c, d), x.dtype)
    bidx = jnp.arange(b)[:, None]                                  # (B,1)
    ids_flat = ids.reshape(b, s * k)
    pos_flat = pos_safe.reshape(b, s * k)
    src = jnp.broadcast_to(x[:, :, None, :], (b, s, k, d))
    src = jnp.where(keep[..., None], src, jnp.zeros((), x.dtype)).reshape(b, s * k, d)
    buf = buf.at[bidx, ids_flat, pos_flat].add(src)
    expert_in = jnp.moveaxis(buf, 1, 0).reshape(e, b, c, d)
    expert_out = _expert_ffn(expert_in, p, cfg).astype(jnp.float32)
    expert_out = jnp.moveaxis(expert_out.reshape(e, b, c, d), 0, 1)  # (B,E,C,D)

    # Gather back and weight.
    gathered = expert_out[bidx, ids_flat, pos_flat].reshape(b, s, k, d)
    out = (gathered * (weights * keep)[..., None]).sum(2)
    return out.astype(x.dtype), aux
