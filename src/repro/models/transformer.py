"""Decoder-only language model covering dense / MoE / SSM / hybrid families.

Layer stacks are organized as *superblocks*: the repeating unit of the
config's block pattern (a single block for homogeneous models, Griffin's
(rglru, rglru, attn) for hybrids).  Superblock parameters are stacked along
a leading "layers" axis and the stack is traversed with ``lax.scan`` so the
lowered HLO is depth-independent — essential for compiling a 126-layer
405B model with 512 host devices in the dry-run.

Three entry points per model:
  forward_train(params, batch)          -> (loss, aux)
  prefill(params, tokens, ...)          -> (logits_last, caches)
  decode_step(params, caches, token, pos) -> (logits, caches)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import griffin, layers, moe, ssm
from repro.models.attention import KVCacheSpec
from repro.models.config import ModelConfig
from repro.models.params import decl, is_decl, tree_map_decls


# ---------------------------------------------------------------------------
# Block pattern handling
# ---------------------------------------------------------------------------

def block_pattern(cfg: ModelConfig) -> tuple[str, ...]:
    if cfg.arch_type == "dense":
        return ("attn_mlp",)
    if cfg.arch_type == "moe":
        return ("attn_moe",)
    if cfg.arch_type == "ssm":
        return ("ssm",)
    if cfg.arch_type == "hybrid":
        return tuple("attn_mlp" if b == "attn" else "rglru_mlp" for b in cfg.block_pattern)
    raise ValueError(cfg.arch_type)


def super_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(full superblocks, remainder sub-blocks)."""
    p = len(block_pattern(cfg))
    return cfg.num_layers // p, cfg.num_layers % p


def _block_decls(kind: str, cfg: ModelConfig) -> dict:
    if kind == "attn_mlp":
        return {
            "ln1": layers.rmsnorm_decls(cfg.d_model),
            "attn": attn.attention_decls(cfg),
            "ln2": layers.rmsnorm_decls(cfg.d_model),
            "mlp": layers.ffn_decls(cfg.d_model, cfg.d_ff, cfg.ffn_type),
        }
    if kind == "attn_moe":
        return {
            "ln1": layers.rmsnorm_decls(cfg.d_model),
            "attn": attn.attention_decls(cfg),
            "ln2": layers.rmsnorm_decls(cfg.d_model),
            "moe": moe.moe_decls(cfg),
        }
    if kind == "ssm":
        return {"ln1": layers.rmsnorm_decls(cfg.d_model), "ssm": ssm.ssm_decls(cfg)}
    if kind == "rglru_mlp":
        return {
            "ln1": layers.rmsnorm_decls(cfg.d_model),
            "rec": griffin.rglru_decls(cfg),
            "ln2": layers.rmsnorm_decls(cfg.d_model),
            "mlp": layers.ffn_decls(cfg.d_model, cfg.d_ff, cfg.ffn_type),
        }
    raise ValueError(kind)


def _superblock_decls(cfg: ModelConfig) -> dict:
    return {f"b{i}_{k}": _block_decls(k, cfg) for i, k in enumerate(block_pattern(cfg))}


def _stack(decl_tree, n: int):
    return tree_map_decls(
        lambda d: decl((n, *d.shape), ("layers", *d.axes), d.init, d.scale), decl_tree
    )


def model_decls(cfg: ModelConfig) -> dict:
    n_super, rem = super_counts(cfg)
    pat = block_pattern(cfg)
    out: dict[str, Any] = {
        "embed": layers.embed_decls(cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings),
        "final_norm": layers.rmsnorm_decls(cfg.d_model),
        "blocks": _stack(_superblock_decls(cfg), n_super),
    }
    if rem:
        out["tail"] = {
            f"t{i}_{pat[i]}": _block_decls(pat[i], cfg) for i in range(rem)
        }
    return out


# ---------------------------------------------------------------------------
# Block forward (full sequence)
# ---------------------------------------------------------------------------

def _window_for(kind_idx_window: int, cfg: ModelConfig) -> int:
    if cfg.arch_type == "hybrid":
        return cfg.attention_window
    return cfg.sliding_window


def _block_fwd(kind: str, x, p, cfg: ModelConfig, positions):
    """Full-sequence forward.  Returns (x, aux_loss, cache_seed)."""
    if kind in ("attn_mlp", "attn_moe"):
        h, kv = attn.self_attention(
            layers.rms_norm(x, p["ln1"], cfg.norm_eps), p["attn"], cfg, positions,
            causal=True, window=_window_for(0, cfg),
        )
        x = x + h
        y = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "attn_mlp":
            x = x + layers.ffn(y, p["mlp"], cfg.ffn_type)
            return x, jnp.float32(0.0), kv
        moe_fn = moe.moe_ffn_grouped if cfg.moe_impl == "grouped" else moe.moe_ffn
        mo, aux = moe_fn(y, p["moe"], cfg, capacity_factor=cfg.moe_capacity_factor)
        return x + mo, aux, kv
    if kind == "ssm":
        h, state = ssm.ssm_block(layers.rms_norm(x, p["ln1"], cfg.norm_eps), p["ssm"], cfg)
        return x + h, jnp.float32(0.0), state  # state = (conv_tail, h)
    if kind == "rglru_mlp":
        h, state = griffin.recurrent_block(
            layers.rms_norm(x, p["ln1"], cfg.norm_eps), p["rec"], cfg
        )
        x = x + h
        x = x + layers.ffn(layers.rms_norm(x, p["ln2"], cfg.norm_eps), p["mlp"], cfg.ffn_type)
        return x, jnp.float32(0.0), state
    raise ValueError(kind)


def _superblock_fwd(x, sp, cfg: ModelConfig, positions, collect_cache: bool):
    aux_total = jnp.float32(0.0)
    seeds = {}
    for name, p in sp.items():
        kind = name.split("_", 1)[1]
        x, aux, seed = _block_fwd(kind, x, p, cfg, positions)
        aux_total = aux_total + aux
        if collect_cache:
            seeds[name] = seed
    return x, aux_total, seeds


def _run_stack(x, params, cfg: ModelConfig, positions, collect_cache: bool = False):
    """Scan over stacked superblocks + unrolled tail."""
    def body(carry, sp):
        xx, aux = carry
        xx, aux_sb, seeds = _superblock_fwd(xx, sp, cfg, positions, collect_cache)
        return (xx, aux + aux_sb), seeds if collect_cache else 0

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), seeds = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["blocks"])
    tail_seeds = {}
    if "tail" in params:
        for name, p in params["tail"].items():
            kind = name.split("_", 1)[1]
            x, a, seed = _block_fwd(kind, x, p, cfg, positions)
            aux = aux + a
            if collect_cache:
                tail_seeds[name] = seed
    return x, aux, seeds, tail_seeds


# ---------------------------------------------------------------------------
# Frontend (VLM stub): precomputed patch embeddings overwrite the first
# `frontend_tokens` positions of the token embedding sequence.
# ---------------------------------------------------------------------------

def _apply_frontend(x, batch):
    fe = batch.get("frontend_embeds")
    if fe is None:
        return x
    return jax.lax.dynamic_update_slice(x, fe.astype(x.dtype), (0, 0, 0))


def _positions(batch, cfg: ModelConfig, b: int, s: int):
    if cfg.mrope:
        p3 = batch.get("positions3")
        if p3 is None:
            base = jnp.arange(s, dtype=jnp.int32)[None, :, None]
            p3 = jnp.broadcast_to(base, (b, s, 3))
        return p3
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward_logits(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = layers.embed(tokens, params["embed"])
    x = _apply_frontend(x, batch)
    positions = _positions(batch, cfg, b, s)
    x, aux, _, _ = _run_stack(x, params, cfg, positions)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return layers.unembed(x, params["embed"]), aux


def forward_train(params, batch, cfg: ModelConfig, aux_weight: float = 0.01):
    logits, aux = forward_logits(params, batch, cfg)
    loss = layers.cross_entropy_loss(logits, batch["labels"], cfg.padded_vocab)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# -- caches ------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, max_len: int) -> KVCacheSpec:
    window = cfg.sliding_window or (cfg.attention_window if cfg.arch_type == "hybrid" else 0)
    if window:
        return KVCacheSpec(size=min(window, max_len), window=window)
    return KVCacheSpec(size=max_len, window=0)


def _block_cache_decls(kind: str, cfg: ModelConfig, batch: int, spec: KVCacheSpec):
    if kind in ("attn_mlp", "attn_moe"):
        return attn.kv_cache_decls(cfg, batch, spec)
    if kind == "ssm":
        return ssm.ssm_cache_decls(cfg, batch)
    if kind == "rglru_mlp":
        return griffin.rglru_cache_decls(cfg, batch)
    raise ValueError(kind)


def cache_decls(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    spec = cache_spec(cfg, max_len)
    n_super, rem = super_counts(cfg)
    pat = block_pattern(cfg)
    sb = {
        f"b{i}_{k}": _block_cache_decls(k, cfg, batch, spec)
        for i, k in enumerate(pat)
    }
    out = {"blocks": _stack(sb, n_super)}
    if rem:
        out["tail"] = {
            f"t{i}_{pat[i]}": _block_cache_decls(pat[i], cfg, batch, spec)
            for i in range(rem)
        }
    return out


# -- prefill -----------------------------------------------------------------

def _seed_to_cache(kind: str, seed, cfg: ModelConfig, spec: KVCacheSpec, s: int):
    """Convert a full-sequence cache seed into the decode cache layout."""
    if kind in ("attn_mlp", "attn_moe"):
        k, v = seed  # (..., B, S, KV, Dh); leading layer axis when stacked

        def to_cache(x):
            if s >= spec.size:
                x = x[..., s - spec.size : s, :, :]
                if spec.window > 0:  # rolling layout: token t lives at t % size
                    x = jnp.roll(x, s % spec.size, axis=-3)
            else:
                pad = [(0, 0)] * (x.ndim - 3) + [(0, spec.size - s), (0, 0), (0, 0)]
                x = jnp.pad(x, pad)
            return x

        return {"k": to_cache(k), "v": to_cache(v)}
    conv_tail, h = seed
    return {"conv": conv_tail, "h": h.astype(jnp.float32)}


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Full-sequence forward that also builds decode caches.

    Returns (logits_last (B, V), caches, aux).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    spec = cache_spec(cfg, max_len)
    x = layers.embed(tokens, params["embed"])
    x = _apply_frontend(x, batch)
    positions = _positions(batch, cfg, b, s)
    x, aux, seeds, tail_seeds = _run_stack(x, params, cfg, positions, collect_cache=True)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x[:, -1:], params["embed"])[:, 0]
    caches = {
        "blocks": {
            name: _seed_to_cache(name.split("_", 1)[1], seed, cfg, spec, s)
            for name, seed in seeds.items()
        }
    }
    if tail_seeds:
        caches["tail"] = {
            name: _seed_to_cache(name.split("_", 1)[1], seed, cfg, spec, s)
            for name, seed in tail_seeds.items()
        }
    return logits, caches, aux


# -- decode ------------------------------------------------------------------

def _block_decode(kind: str, x, cache, p, cfg: ModelConfig, pos, spec: KVCacheSpec):
    if kind in ("attn_mlp", "attn_moe"):
        h, new_cache = attn.decode_self_attention(
            layers.rms_norm(x, p["ln1"], cfg.norm_eps), cache, p["attn"], cfg, pos, spec
        )
        x = x + h
        y = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "attn_mlp":
            return x + layers.ffn(y, p["mlp"], cfg.ffn_type), new_cache
        moe_fn = moe.moe_ffn_grouped if cfg.moe_impl == "grouped" else moe.moe_ffn
        mo, _ = moe_fn(y, p["moe"], cfg, capacity_factor=2.0)
        return x + mo, new_cache
    if kind == "ssm":
        h, new_cache = ssm.ssm_decode_step(
            layers.rms_norm(x, p["ln1"], cfg.norm_eps), cache, p["ssm"], cfg
        )
        return x + h, new_cache
    if kind == "rglru_mlp":
        h, new_cache = griffin.recurrent_decode_step(
            layers.rms_norm(x, p["ln1"], cfg.norm_eps), cache, p["rec"], cfg
        )
        x = x + h
        x = x + layers.ffn(layers.rms_norm(x, p["ln2"], cfg.norm_eps), p["mlp"], cfg.ffn_type)
        return x, new_cache
    raise ValueError(kind)


def decode_step(params, caches, token, pos, cfg: ModelConfig, max_len: int):
    """token (B,) int32; pos () int32 -> (logits (B,V), new caches)."""
    spec = cache_spec(cfg, max_len)
    x = layers.embed(token[:, None], params["embed"])

    def body(carry, scanned):
        xx = carry
        sp, scache = scanned
        new_caches = {}
        for name in sp:
            kind = name.split("_", 1)[1]
            xx, nc = _block_decode(kind, xx, scache[name], sp[name], cfg, pos, spec)
            new_caches[name] = nc
        return xx, new_caches

    x, new_block_caches = jax.lax.scan(body, x, (params["blocks"], caches["blocks"]))
    new_caches = {"blocks": new_block_caches}
    if "tail" in params:
        new_caches["tail"] = {}
        for name, p in params["tail"].items():
            kind = name.split("_", 1)[1]
            x, nc = _block_decode(kind, x, caches["tail"][name], p, cfg, pos, spec)
            new_caches["tail"][name] = nc
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x, params["embed"])
    return logits[:, 0], new_caches
