"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Block: in_proj -> (z | x | B | C | dt), causal depthwise conv over (x,B,C),
SiLU, softplus(dt), chunked SSD scan (Pallas kernel on TPU), gated RMSNorm,
out_proj.  Decode keeps a (conv window, SSD state) pair per layer — O(1) in
sequence length, which is what qualifies Mamba-2 for ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ops as ssd_ops
from repro.models.config import ModelConfig
from repro.models.params import decl


def _dims(cfg: ModelConfig):
    di = cfg.ssm_d_inner
    n = cfg.ssm_state_dim
    nh = cfg.ssm_num_heads
    conv_ch = di + 2 * n
    return di, n, nh, conv_ch


def ssm_decls(cfg: ModelConfig):
    d = cfg.d_model
    di, n, nh, conv_ch = _dims(cfg)
    return {
        "w_in": decl((d, 2 * di + 2 * n + nh), ("embed", "ffn")),
        "conv_w": decl((cfg.ssm_conv_width, conv_ch), (None, "ffn"), scale=0.5),
        "conv_b": decl((conv_ch,), ("ffn",), init="zeros"),
        "A_log": decl((nh,), (None,), init="ones"),
        "dt_bias": decl((nh,), (None,), init="zeros"),
        "D": decl((nh,), (None,), init="ones"),
        "norm_scale": decl((di,), ("ffn",), init="ones"),
        "w_out": decl((di, d), ("ffn", "embed")),
    }


def _split(zxbcdt, cfg: ModelConfig):
    di, n, nh, _ = _dims(cfg)
    z, xc, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    return z, xc, Bm, Cm, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, x (B,S,C), w (W,C): out_t = Σ_k w_k x_{t-W+1+k}."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for k in range(width):
        out = out + pad[:, k : k + s].astype(jnp.float32) * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _gated_rmsnorm(y, z, scale, eps):
    yf = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def ssm_block(x: jnp.ndarray, p, cfg: ModelConfig):
    """Train/prefill forward; x (B,S,D) -> (out, final_state)."""
    b, s, _ = x.shape
    di, n, nh, conv_ch = _dims(cfg)
    z, xc, Bm, Cm, dt = _split(x @ p["w_in"], cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    xc, Bm, Cm = jnp.split(conv_out, [di, di + n], axis=-1)
    xh = xc.reshape(b, s, nh, cfg.ssm_head_dim)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = ssd_ops.ssd(xh, dtp, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk_size)
    y = _gated_rmsnorm(y.reshape(b, s, di), z, p["norm_scale"], cfg.norm_eps)
    conv_tail = conv_in[:, -(cfg.ssm_conv_width - 1):]  # decode continuation
    return y @ p["w_out"], (conv_tail, h)


# ---------------------------------------------------------------------------
# Decode (O(1) state)
# ---------------------------------------------------------------------------

def ssm_cache_decls(cfg: ModelConfig, batch: int):
    di, n, nh, conv_ch = _dims(cfg)
    return {
        "conv": decl(
            (batch, cfg.ssm_conv_width - 1, conv_ch),
            ("cache_batch", None, "kv_heads"), init="zeros",
        ),
        "h": decl(
            (batch, nh, cfg.ssm_head_dim, n),
            ("cache_batch", "kv_heads", None, None), init="zeros", dtype="float32",
        ),
    }


def ssm_decode_step(x: jnp.ndarray, cache, p, cfg: ModelConfig):
    """x (B,1,D) -> (out (B,1,D), new_cache)."""
    b = x.shape[0]
    di, n, nh, conv_ch = _dims(cfg)
    z, xc, Bm, Cm, dt = _split(x[:, 0] @ p["w_in"], cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)               # (B, C)
    window = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    conv_out = (window.astype(jnp.float32) * w[None]).sum(1) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    xc, Bm, Cm = jnp.split(conv_out, [di, di + n], axis=-1)
    xh = xc.reshape(b, nh, cfg.ssm_head_dim)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = ssd_ops.ssd_decode_step(xh, dtp, A, Bm, Cm, p["D"], cache["h"])
    y = _gated_rmsnorm(y.reshape(b, di), z, p["norm_scale"], cfg.norm_eps)
    out = (y @ p["w_out"])[:, None]
    return out, {"conv": window[:, 1:], "h": h}
