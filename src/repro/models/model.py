"""Unified model API over all architecture families + input-shape specs.

``build_model(cfg)`` returns a ``ModelApi`` with the three entry points the
launcher, serving engine and dry-run use.  ``input_specs`` builds
ShapeDtypeStruct stand-ins for every model input for a given workload shape
(never allocating), and ``concrete_inputs`` builds small real batches for
CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.models.params import abstract_params, init_params


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One workload shape from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

_ENC_LEN_DECODE = 4_096  # encoder length for enc-dec decode shapes


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """DESIGN.md §Arch-applicability: long_500k needs sub-quadratic state."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full attention: 500k dense KV is the excluded quadratic-state regime"
    return True, ""


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    param_decls: dict
    train_loss: Callable          # (params, batch) -> (loss, metrics)
    prefill: Callable             # (params, batch, max_len) -> (logits, caches[, aux])
    decode_step: Callable         # (params, caches, token, pos, max_len) -> (logits, caches)
    cache_decls: Callable         # (batch, max_len) -> decl tree

    def abstract(self, dtype=jnp.bfloat16):
        return abstract_params(self.param_decls, dtype)

    def init(self, key, dtype=jnp.bfloat16):
        return init_params(self.param_decls, key, dtype)


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.arch_type == "encdec":
        return ModelApi(
            cfg=cfg,
            param_decls=encdec.model_decls(cfg),
            train_loss=lambda p, b: encdec.forward_train(p, b, cfg),
            prefill=lambda p, b, max_len: encdec.prefill(p, b, cfg, max_len),
            decode_step=lambda p, c, t, pos, max_len: encdec.decode_step(p, c, t, pos, cfg, max_len),
            cache_decls=lambda batch, max_len: encdec.cache_decls(
                cfg, batch, max_len, _ENC_LEN_DECODE
            ),
        )
    return ModelApi(
        cfg=cfg,
        param_decls=transformer.model_decls(cfg),
        train_loss=lambda p, b: transformer.forward_train(p, b, cfg),
        prefill=lambda p, b, max_len: transformer.prefill(p, b, cfg, max_len)[:2],
        decode_step=lambda p, c, t, pos, max_len: transformer.decode_step(p, c, t, pos, cfg, max_len),
        cache_decls=lambda batch, max_len: transformer.cache_decls(cfg, batch, max_len),
    )


# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------

def _batch_struct(cfg: ModelConfig, b: int, s: int, mode: str, dtype) -> dict[str, Any]:
    """Shapes of the model-input batch (shared by specs and concrete)."""
    out: dict[str, Any] = {}
    if cfg.arch_type == "encdec":
        out["frontend_embeds"] = ((b, s if mode == "train" else _ENC_LEN_DECODE, cfg.d_model), dtype)
        if mode != "decode":
            out["tokens"] = ((b, s), jnp.int32)
    else:
        if mode != "decode":
            out["tokens"] = ((b, s), jnp.int32)
        if cfg.frontend == "vision":
            out["frontend_embeds"] = ((b, min(cfg.frontend_tokens, s), cfg.d_model), dtype)
            if cfg.mrope and mode != "decode":
                out["positions3"] = ((b, s, 3), jnp.int32)
    if mode == "train":
        out["labels"] = ((b, s), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    struct = _batch_struct(cfg, shape.global_batch, shape.seq_len, shape.mode, dtype)
    return {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt) in struct.items()}


def decode_token_specs(shape: InputShape) -> tuple:
    """(token, pos) stand-ins for decode shapes."""
    return (
        jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )


def concrete_inputs(cfg: ModelConfig, shape: InputShape, key, dtype=jnp.bfloat16) -> dict:
    """Small real batches for smoke tests (reduced configs only)."""
    struct = _batch_struct(cfg, shape.global_batch, shape.seq_len, shape.mode, dtype)
    rng = np.random.default_rng(np.asarray(jax.random.key_data(key)).ravel()[-1])
    out = {}
    for k, (sh, dt) in struct.items():
        if dt == jnp.int32:
            if k == "positions3":
                base = np.broadcast_to(np.arange(sh[1])[None, :, None], sh)
                out[k] = jnp.asarray(base, jnp.int32)
            else:
                out[k] = jnp.asarray(rng.integers(0, cfg.vocab_size, sh), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(sh) * 0.02, dtype)
    return out
