"""Model configuration covering all assigned architecture families.

One frozen dataclass describes dense / MoE / SSM / hybrid / enc-dec models
(VLM and audio backbones are dense / enc-dec configs with a stubbed modality
frontend).  Every assigned architecture in ``repro/configs`` instantiates
exactly the published numbers and cites its source.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

ArchType = Literal["dense", "moe", "ssm", "hybrid", "encdec"]
Frontend = Literal["none", "vision", "audio"]


def pad_vocab(vocab_size: int, multiple: int = 256) -> int:
    """Round the embedding table up for even `model`-axis sharding."""
    return ((vocab_size + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: ArchType
    num_layers: int
    d_model: int
    vocab_size: int

    # Attention (unused for pure SSM).
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10_000.0
    mrope: bool = False                  # Qwen2-VL multimodal RoPE
    sliding_window: int = 0              # 0 = full causal attention

    # FFN.
    d_ff: int = 0
    ffn_type: Literal["swiglu", "gelu"] = "swiglu"

    # MoE.
    num_experts: int = 0
    experts_per_token: int = 0
    moe_impl: Literal["einsum", "grouped"] = "einsum"
    moe_capacity_factor: float = 1.25

    # SSM (Mamba-2 / SSD).
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk_size: int = 128

    # Hybrid (RecurrentGemma / Griffin).
    block_pattern: tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "attn")
    attention_window: int = 0            # local-attention window (hybrid)
    lru_width: int = 0

    # Encoder-decoder.
    encoder_layers: int = 0

    # Modality frontend stub (precomputed embeddings consumed as-is).
    frontend: Frontend = "none"
    frontend_tokens: int = 0             # patches / audio frames per example

    # Numerics / training.
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: bool = True

    source: str = ""                     # citation for the exact numbers

    def __post_init__(self):
        if self.arch_type != "ssm" and self.num_heads:
            if self.head_dim == 0:
                object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.arch_type == "moe" and not (self.num_experts and self.experts_per_token):
            raise ValueError(f"{self.name}: MoE config needs experts")
        if self.arch_type == "hybrid" and not self.block_pattern:
            raise ValueError(f"{self.name}: hybrid config needs block_pattern")

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic state at decode: SSM / hybrid / sliding-window."""
        return (
            self.arch_type in ("ssm", "hybrid")
            or self.sliding_window > 0
        )

    @property
    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.padded_vocab
        embed = v * d * (1 if self.tie_embeddings else 2)
        if self.arch_type == "ssm":
            di, ns = self.ssm_d_inner, self.ssm_state_dim
            nh = self.ssm_num_heads
            # in_proj (z,x,B,C,dt) + conv + out_proj + norms
            per_layer = d * (2 * di + 2 * ns + nh) + (di + 2 * ns) * self.ssm_conv_width
            per_layer += di * d + 2 * nh + di + d
            return embed + self.num_layers * per_layer
        attn = d * self.head_dim * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * self.head_dim * d
        if self.ffn_type == "swiglu":
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        if self.arch_type == "moe":
            ffn = self.num_experts * ffn + d * self.num_experts
        per_layer = attn + ffn + 2 * d
        total = embed + self.num_layers * per_layer
        if self.arch_type == "hybrid":
            # Recompute: attention only on "attn" blocks, RG-LRU on the rest.
            n_attn = sum(
                1 for i in range(self.num_layers)
                if self.block_pattern[i % len(self.block_pattern)] == "attn"
            )
            n_rec = self.num_layers - n_attn
            w = self.lru_width or d
            rec = d * w * 2 + w * self.ssm_conv_width + w * d + 3 * w  # conv+gates+proj
            total = embed + n_attn * (attn + ffn + 2 * d) + n_rec * (rec + ffn + 2 * d)
        if self.arch_type == "encdec":
            # Encoder layers: self-attn + ffn; decoder adds cross-attn.
            enc = self.encoder_layers * (attn + ffn + 2 * d)
            dec = self.num_layers * (2 * attn + ffn + 3 * d)
            total = embed + enc + dec
        return total

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.arch_type != "moe":
            return self.param_count
        d = self.d_model
        ffn_one = (3 if self.ffn_type == "swiglu" else 2) * d * self.d_ff
        inactive = self.num_layers * (self.num_experts - self.experts_per_token) * ffn_one
        return self.param_count - inactive


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """CPU-smoke-test variant: same family, 2 layers, tiny dims.

    Keeps head_dim/ratios structurally faithful (GQA grouping, MoE top-k,
    hybrid pattern) while fitting a laptop.
    """
    small: dict = dict(
        num_layers=2 if cfg.arch_type != "hybrid" else 3,
        d_model=min(cfg.d_model, 128),
        vocab_size=min(cfg.vocab_size, 512),
        frontend_tokens=min(cfg.frontend_tokens, 8),
    )
    if cfg.num_heads:
        heads = min(cfg.num_heads, 4)
        kv = max(1, min(cfg.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        small.update(num_heads=heads, num_kv_heads=kv, head_dim=32)
    if cfg.d_ff:
        small["d_ff"] = min(cfg.d_ff, 256)
    if cfg.arch_type == "moe":
        # Generous capacity so prefill==decode consistency holds exactly in
        # smoke tests (capacity drops only hit the prefill path: decode's
        # per-token dispatch never overflows — a real, documented asymmetry).
        small.update(num_experts=min(cfg.num_experts, 4),
                     experts_per_token=min(cfg.experts_per_token, 2),
                     moe_capacity_factor=4.0)
    if cfg.arch_type == "ssm":
        small.update(ssm_state_dim=min(cfg.ssm_state_dim, 16), ssm_head_dim=32,
                     ssm_chunk_size=16)
    if cfg.arch_type == "hybrid":
        small.update(lru_width=min(cfg.lru_width or cfg.d_model, 128),
                     attention_window=min(cfg.attention_window, 16))
    if cfg.sliding_window:
        small["sliding_window"] = min(cfg.sliding_window, 16)
    if cfg.encoder_layers:
        small["encoder_layers"] = 2
    small.update(overrides)
    small["name"] = cfg.name + "-reduced"
    return dataclasses.replace(cfg, **small)
