"""Shared model building blocks: norms, RoPE / M-RoPE, FFNs, embeddings.

All functions are pure; parameters come in as dict subtrees declared by the
matching ``*_decls`` helpers so shapes, logical sharding axes and init live
in one place (see ``repro.models.params``).

Logical axes used here (mapped to mesh axes in repro.distributed.sharding):
  "embed"   — d_model rows of weight matrices  -> fsdp/data axis
  "ffn"     — FFN hidden dim                   -> model axis
  "heads"   — flattened q-head * head_dim      -> model axis
  "kv"      — flattened kv-head * head_dim     -> model axis (if divisible)
  "vocab"   — embedding/vocab rows             -> model axis
  "experts" — MoE expert dim                   -> expert/data axis
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import decl


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_decls(d_model: int):
    return {"scale": decl((d_model,), ("embed",), init="ones")}


def rms_norm(x: jnp.ndarray, p, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + Qwen2-VL multimodal M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)                         # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float, sections=(2, 1, 1)
) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: positions (B, S, 3) = (temporal, height, width) ids.

    The D/2 rotary frequencies are partitioned into three contiguous
    sections proportional to ``sections`` (arXiv:2409.12191 §2.1); each
    section rotates by its own positional channel.  Text tokens carry equal
    (t,h,w) ids, which makes M-RoPE degenerate to standard RoPE there.
    """
    d = x.shape[-1]
    half = d // 2
    total = sum(sections)
    bounds = []
    start = 0
    for s in sections[:-1]:
        start += (half * s) // total
        bounds.append(start)
    freqs = _rope_freqs(d, theta)                              # (half,)
    sec_id = jnp.zeros((half,), jnp.int32)
    for b in bounds:
        sec_id = sec_id + (jnp.arange(half) >= b).astype(jnp.int32)
    pos_per_freq = jnp.take_along_axis(
        positions.astype(jnp.float32),                         # (B,S,3)
        jnp.broadcast_to(sec_id[None, None, :], positions.shape[:2] + (half,)),
        axis=-1,
    )                                                          # (B,S,half)
    angles = pos_per_freq * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Feed-forward networks
# ---------------------------------------------------------------------------

def ffn_decls(d_model: int, d_ff: int, ffn_type: str):
    if ffn_type == "swiglu":
        return {
            "w_gate": decl((d_model, d_ff), ("embed", "ffn")),
            "w_up": decl((d_model, d_ff), ("embed", "ffn")),
            "w_down": decl((d_ff, d_model), ("ffn", "embed")),
        }
    return {
        "w_in": decl((d_model, d_ff), ("embed", "ffn")),
        "w_out": decl((d_ff, d_model), ("ffn", "embed")),
    }


def ffn(x: jnp.ndarray, p, ffn_type: str) -> jnp.ndarray:
    if ffn_type == "swiglu":
        gate = jax.nn.silu(x @ p["w_gate"])
        return (gate * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_decls(padded_vocab: int, d_model: int, tie: bool):
    d = {"embedding": decl((padded_vocab, d_model), ("vocab", "embed"), scale=1.0)}
    if not tie:
        d["lm_head"] = decl((d_model, padded_vocab), ("embed", "vocab"))
    return d


def embed(tokens: jnp.ndarray, p) -> jnp.ndarray:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(x: jnp.ndarray, p) -> jnp.ndarray:
    w = p.get("lm_head")
    if w is None:
        w = p["embedding"].T
    return x @ w


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray, vocab_size: int) -> jnp.ndarray:
    """Mean next-token CE in fp32; positions with label < 0 are masked."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
