"""GQA attention block: projections + RoPE + kernel-dispatched core.

Supports full-causal, sliding-window (Mixtral), local (RecurrentGemma),
bidirectional (encoder) and cross (enc-dec decoder) attention, plus
one-token decode against a fixed-size or rolling KV cache.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.attention import ops as attn_ops
from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.params import decl


def attention_decls(cfg: ModelConfig, *, kv_from: str = "self"):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "w_q": decl((d, h * hd), ("embed", "heads")),
        "w_k": decl((d, kv * hd), ("embed", "kv")),
        "w_v": decl((d, kv * hd), ("embed", "kv")),
        "w_o": decl((h * hd, d), ("heads", "embed")),
    }


def _project_qkv(x, kv_x, p, cfg: ModelConfig):
    b, s, _ = x.shape
    s_kv = kv_x.shape[1]
    q = (x @ p["w_q"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = (kv_x @ p["w_k"]).reshape(b, s_kv, cfg.num_kv_heads, cfg.head_dim)
    v = (kv_x @ p["w_v"]).reshape(b, s_kv, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def _rope(x, positions, cfg: ModelConfig):
    if cfg.mrope:
        return layers.apply_mrope(x, positions, cfg.rope_theta)
    return layers.apply_rope(x, positions, cfg.rope_theta)


def self_attention(
    x: jnp.ndarray,
    p,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    use_rope: bool = True,
):
    """Full-sequence self-attention (train / prefill).

    Returns (out, (k, v)) so prefill can seed the decode cache.
    """
    q, k, v = _project_qkv(x, x, p, cfg)
    if use_rope:
        q = _rope(q, positions, cfg)
        k = _rope(k, positions, cfg)
    out = attn_ops.flash_attention(q, k, v, causal=causal, window=window)
    b, s, _, _ = q.shape
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["w_o"]
    return out, (k, v)


def cross_attention(x, enc_kv, p, cfg: ModelConfig):
    """Decoder-to-encoder attention; enc_kv = (k, v) precomputed once."""
    b, s, _ = x.shape
    q = (x @ p["w_q"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k, v = enc_kv
    out = attn_ops.flash_attention(q, k, v, causal=False)
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["w_o"]
    return out


def cross_kv(enc_out, p, cfg: ModelConfig):
    b, s, _ = enc_out.shape
    k = (enc_out @ p["w_k"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ p["w_v"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    return k, v


# ---------------------------------------------------------------------------
# Decode (one new token, KV cache)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Fixed-size cache; rolling when window > 0 (slot = pos % size)."""
    size: int
    window: int = 0


def kv_cache_decls(cfg: ModelConfig, batch: int, spec: KVCacheSpec):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    axes = ("cache_batch", "kv_seq", "kv_heads", None)
    return {
        "k": decl((batch, spec.size, kv, hd), axes, init="zeros"),
        "v": decl((batch, spec.size, kv, hd), axes, init="zeros"),
    }


def decode_self_attention(
    x: jnp.ndarray,            # (B, 1, D)
    cache,                     # {"k","v"}: (B, S_cache, KV, Dh)
    p,
    cfg: ModelConfig,
    pos: jnp.ndarray,          # () current token index
    spec: KVCacheSpec,
    *,
    use_rope: bool = True,
    positions3: jnp.ndarray | None = None,  # M-RoPE (B,1,3)
):
    b = x.shape[0]
    q, k, v = _project_qkv(x, x, p, cfg)
    if use_rope:
        pos_b = jnp.broadcast_to(pos[None, None], (b, 1))
        if cfg.mrope:
            p3 = positions3 if positions3 is not None else jnp.broadcast_to(
                pos[None, None, None], (b, 1, 3)
            )
            q = layers.apply_mrope(q, p3, cfg.rope_theta)
            k = layers.apply_mrope(k, p3, cfg.rope_theta)
        else:
            q = layers.apply_rope(q, pos_b, cfg.rope_theta)
            k = layers.apply_rope(k, pos_b, cfg.rope_theta)
    slot = jnp.mod(pos, spec.size) if spec.window > 0 else pos
    k_cache = _update_cache(cache["k"], k[:, 0], slot)
    v_cache = _update_cache(cache["v"], v[:, 0], slot)
    cache_len = jnp.minimum(pos + 1, spec.size)
    out = attn_ops.decode_attention(
        q[:, 0], k_cache, v_cache, cache_len,
        window=0 if spec.window == 0 else min(spec.window, spec.size),
    )
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim) @ p["w_o"]
    return out, {"k": k_cache, "v": v_cache}


def _update_cache(cache: jnp.ndarray, new: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """cache (B,S,KV,Dh) <- new (B,KV,Dh) at position `slot`."""
    return jax.lax.dynamic_update_slice(
        cache, new[:, None].astype(cache.dtype), (0, slot, 0, 0)
    )
