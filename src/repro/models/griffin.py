"""RG-LRU recurrent block + local attention (RecurrentGemma / Griffin,
arXiv:2402.19427).

Temporal-mixing block comes in two flavours selected by the config's
``block_pattern`` (1:2 attention:recurrent for RecurrentGemma):

* recurrent: x -> [gelu gate branch | conv -> RG-LRU branch] -> merge -> proj
  RG-LRU:  r_t = σ(W_r x_t);  i_t = σ(W_i x_t)
           a_t = exp(-c · softplus(Λ) · r_t)          (c = 8)
           h_t = a_t h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)
  Train/prefill uses ``jax.lax.associative_scan`` (log-depth on TPU);
  decode is the O(1) recurrence.
* attn: GQA/MQA local (sliding-window) attention, window = 2048.

State per recurrent layer: (conv window, h) — O(1) in sequence length,
qualifying the hybrid for ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import decl

_RGLRU_C = 8.0


def rglru_decls(cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "w_gate_branch": decl((d, w), ("embed", "ffn")),
        "w_x_branch": decl((d, w), ("embed", "ffn")),
        "conv_w": decl((cfg.ssm_conv_width, w), (None, "ffn"), scale=0.5),
        "conv_b": decl((w,), ("ffn",), init="zeros"),
        "w_input_gate": decl((w, w), ("ffn", None)),
        "b_input_gate": decl((w,), ("ffn",), init="zeros"),
        "w_rec_gate": decl((w, w), ("ffn", None)),
        "b_rec_gate": decl((w,), ("ffn",), init="zeros"),
        "lambda_param": decl((w,), ("ffn",), init="ones"),
        "w_out": decl((w, d), ("ffn", "embed")),
    }


def _causal_conv(x, w, b):
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    s = x.shape[1]
    for k in range(width):
        out = out + pad[:, k : k + s].astype(jnp.float32) * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _rglru_gates(xb, p):
    """Returns (a, b) of the linear recurrence h_t = a_t h + b_t, fp32."""
    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_rec_gate"].astype(jnp.float32) + p["b_rec_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_input_gate"].astype(jnp.float32) + p["b_input_gate"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lambda_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, b


def rglru_scan(xb: jnp.ndarray, p, h0: jnp.ndarray | None = None):
    """xb (B,S,W) -> (h_seq (B,S,W), h_final (B,W)) via associative scan."""
    a, b = _rglru_gates(xb, p)
    if h0 is not None:
        # Fold the carried state into the first step's additive term.
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h_seq = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h_seq.astype(xb.dtype), h_seq[:, -1]  # final state stays fp32


def recurrent_block(x: jnp.ndarray, p, cfg: ModelConfig, h0=None):
    """Griffin recurrent temporal block; x (B,S,D) -> (out, (conv_tail, h))."""
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    xb = x @ p["w_x_branch"]
    conv = _causal_conv(xb, p["conv_w"], p["conv_b"])
    h_seq, h_fin = rglru_scan(conv, p, h0)
    out = (h_seq * gate) @ p["w_out"]
    width = cfg.ssm_conv_width
    conv_tail = xb[:, -(width - 1):]  # last W-1 pre-conv inputs for decode
    return out, (conv_tail, h_fin)


def rglru_cache_decls(cfg: ModelConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": decl((batch, cfg.ssm_conv_width - 1, w), ("cache_batch", None, "kv_heads"), init="zeros"),
        "h": decl((batch, w), ("cache_batch", "kv_heads"), init="zeros", dtype="float32"),
    }


def recurrent_decode_step(x: jnp.ndarray, cache, p, cfg: ModelConfig):
    """x (B,1,D) -> (out (B,1,D), new_cache); O(1) per token."""
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate_branch"])
    xb = x[:, 0] @ p["w_x_branch"]
    window = jnp.concatenate([cache["conv"], xb[:, None]], axis=1)
    wconv = p["conv_w"].astype(jnp.float32)
    conv = (window.astype(jnp.float32) * wconv[None]).sum(1) + p["conv_b"].astype(jnp.float32)
    conv = conv.astype(x.dtype)
    a, b = _rglru_gates(conv[:, None], p)
    h = a[:, 0] * cache["h"].astype(jnp.float32) + b[:, 0]
    out = ((h.astype(x.dtype) * gate) @ p["w_out"])[:, None]
    return out, {"conv": window[:, 1:], "h": h.astype(jnp.float32)}
