"""Parameter declaration trees: one source of truth for shape, sharding and init.

A model builder returns a nested dict of ``ParamDecl``; from it we derive
(a) ``ShapeDtypeStruct`` trees for the multi-pod dry-run, (b)
``PartitionSpec`` trees via the logical-axis rules in
``repro.distributed.sharding``, and (c) materialized arrays for CPU smoke
tests and the end-to-end examples.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical axis name per dim
    init: str = "normal"           # normal | zeros | ones
    scale: float | None = None     # default: 1/sqrt(fan_in)
    dtype: str | None = None       # override (e.g. f32 recurrent state)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def decl(shape, axes, init="normal", scale=None, dtype=None) -> ParamDecl:
    return ParamDecl(tuple(shape), tuple(axes), init, scale, dtype)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def tree_map_decls(fn: Callable, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_decl)


def abstract_params(decl_tree, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree — what the dry-run lowers against."""
    return tree_map_decls(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype), decl_tree
    )


def init_params(decl_tree, key: jax.Array, dtype=jnp.bfloat16):
    """Materialize parameters (smoke tests / examples; never the dry-run)."""
    leaves, treedef = jax.tree_util.tree_flatten(decl_tree, is_leaf=is_decl)
    keys = jax.random.split(key, len(leaves))

    def make(d: ParamDecl, k):
        dt = d.dtype or dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        scale = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt)

    return jax.tree_util.tree_unflatten(
        treedef, [make(d, k) for d, k in zip(leaves, keys)]
    )


def param_bytes(decl_tree, bytes_per_el: int = 2) -> int:
    leaves = jax.tree_util.tree_leaves(
        tree_map_decls(lambda d: math.prod(d.shape), decl_tree)
    )
    return sum(leaves) * bytes_per_el


def count_params(decl_tree) -> int:
    return param_bytes(decl_tree, 1)
