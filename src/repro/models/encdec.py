"""Encoder-decoder transformer (SeamlessM4T v2 text/speech backbone,
arXiv:2308.11596).

The audio frontend (mel filterbank + conformer feature extractor) is a STUB
per the assignment: the encoder consumes precomputed frame embeddings
(B, S_enc, d_model).  Encoder blocks are bidirectional self-attention +
FFN; decoder blocks add causal self-attention with a KV cache plus cross
attention against encoder output (cross K/V computed once per request).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers
from repro.models.attention import KVCacheSpec
from repro.models.config import ModelConfig
from repro.models.params import decl, tree_map_decls


def _stack(decl_tree, n: int):
    return tree_map_decls(
        lambda d: decl((n, *d.shape), ("layers", *d.axes), d.init, d.scale, d.dtype),
        decl_tree,
    )


def _enc_block_decls(cfg: ModelConfig):
    return {
        "ln1": layers.rmsnorm_decls(cfg.d_model),
        "attn": attn.attention_decls(cfg),
        "ln2": layers.rmsnorm_decls(cfg.d_model),
        "mlp": layers.ffn_decls(cfg.d_model, cfg.d_ff, cfg.ffn_type),
    }


def _dec_block_decls(cfg: ModelConfig):
    return {
        "ln1": layers.rmsnorm_decls(cfg.d_model),
        "self_attn": attn.attention_decls(cfg),
        "ln_x": layers.rmsnorm_decls(cfg.d_model),
        "cross_attn": attn.attention_decls(cfg),
        "ln2": layers.rmsnorm_decls(cfg.d_model),
        "mlp": layers.ffn_decls(cfg.d_model, cfg.d_ff, cfg.ffn_type),
    }


def model_decls(cfg: ModelConfig) -> dict:
    return {
        "embed": layers.embed_decls(cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings),
        "enc_blocks": _stack(_enc_block_decls(cfg), cfg.encoder_layers),
        "enc_norm": layers.rmsnorm_decls(cfg.d_model),
        "dec_blocks": _stack(_dec_block_decls(cfg), cfg.num_layers),
        "final_norm": layers.rmsnorm_decls(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params, frame_embeds: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frame_embeds (B, S_enc, D) -> encoder output (B, S_enc, D)."""
    b, s, _ = frame_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p):
        h, _ = attn.self_attention(
            layers.rms_norm(x, p["ln1"], cfg.norm_eps), p["attn"], cfg, positions,
            causal=False,
        )
        x = x + h
        x = x + layers.ffn(layers.rms_norm(x, p["ln2"], cfg.norm_eps), p["mlp"], cfg.ffn_type)
        return x, 0

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, frame_embeds.astype(params["enc_norm"]["scale"].dtype),
                        params["enc_blocks"])
    return layers.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder (full sequence: train / prefill)
# ---------------------------------------------------------------------------

def _decode_stack(params, x, enc_out, cfg: ModelConfig, positions, collect_cache: bool):
    def body(xx, p):
        h, kv = attn.self_attention(
            layers.rms_norm(xx, p["ln1"], cfg.norm_eps), p["self_attn"], cfg, positions,
            causal=True,
        )
        xx = xx + h
        ckv = attn.cross_kv(enc_out, p["cross_attn"], cfg)
        xx = xx + attn.cross_attention(
            layers.rms_norm(xx, p["ln_x"], cfg.norm_eps), ckv, p["cross_attn"], cfg
        )
        xx = xx + layers.ffn(layers.rms_norm(xx, p["ln2"], cfg.norm_eps), p["mlp"], cfg.ffn_type)
        out = (kv, ckv) if collect_cache else 0
        return xx, out

    body_fn = jax.checkpoint(body) if cfg.remat else body
    return jax.lax.scan(body_fn, x, params["dec_blocks"])


def forward_train(params, batch, cfg: ModelConfig, aux_weight: float = 0.0):
    """batch: frontend_embeds (B,S_enc,D), tokens (B,S_dec), labels."""
    enc_out = encode(params, batch["frontend_embeds"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = layers.embed(tokens, params["embed"])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = _decode_stack(params, x, enc_out, cfg, positions, collect_cache=False)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x, params["embed"])
    loss = layers.cross_entropy_loss(logits, batch["labels"], cfg.padded_vocab)
    return loss, {"ce": loss, "aux": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Serving: prefill (encoder + decoder prompt) and one-token decode
# ---------------------------------------------------------------------------

def cache_decls(cfg: ModelConfig, batch: int, max_len: int, enc_len: int) -> dict:
    spec = KVCacheSpec(size=max_len)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "self": _stack(attn.kv_cache_decls(cfg, batch, spec), cfg.num_layers),
        "cross_k": decl(
            (cfg.num_layers, batch, enc_len, kv, hd),
            ("layers", "cache_batch", "kv_seq", "kv_heads", None), init="zeros",
        ),
        "cross_v": decl(
            (cfg.num_layers, batch, enc_len, kv, hd),
            ("layers", "cache_batch", "kv_seq", "kv_heads", None), init="zeros",
        ),
    }


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Encoder pass + decoder prompt pass; returns (logits_last, caches)."""
    enc_out = encode(params, batch["frontend_embeds"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = layers.embed(tokens, params["embed"])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, caches_seq = _decode_stack(params, x, enc_out, cfg, positions, collect_cache=True)
    (k_seq, v_seq), (ck, cv) = caches_seq
    pad = max_len - s
    k_cache = jnp.pad(k_seq, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_cache = jnp.pad(v_seq, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x[:, -1:], params["embed"])[:, 0]
    caches = {"self": {"k": k_cache, "v": v_cache}, "cross_k": ck, "cross_v": cv}
    return logits, caches


def decode_step(params, caches, token, pos, cfg: ModelConfig, max_len: int):
    """token (B,) -> (logits (B,V), new caches); cross K/V are static."""
    spec = KVCacheSpec(size=max_len)
    x = layers.embed(token[:, None], params["embed"])

    def body(xx, scanned):
        p, kcache, ckv_k, ckv_v = scanned
        h, nc = attn.decode_self_attention(
            layers.rms_norm(xx, p["ln1"], cfg.norm_eps), kcache, p["self_attn"],
            cfg, pos, spec,
        )
        xx = xx + h
        xx = xx + attn.cross_attention(
            layers.rms_norm(xx, p["ln_x"], cfg.norm_eps), (ckv_k, ckv_v),
            p["cross_attn"], cfg,
        )
        xx = xx + layers.ffn(layers.rms_norm(xx, p["ln2"], cfg.norm_eps), p["mlp"], cfg.ffn_type)
        return xx, nc

    x, new_self = jax.lax.scan(
        body, x,
        (params["dec_blocks"], caches["self"], caches["cross_k"], caches["cross_v"]),
    )
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x, params["embed"])
    new_caches = {"self": new_self, "cross_k": caches["cross_k"], "cross_v": caches["cross_v"]}
    return logits[:, 0], new_caches
