"""Multi-agent serving engine: the paper's allocator as a first-class
scheduler over a fleet of real models.

The TPU-native reading of "allocate GPU fraction g_i to agent i" (DESIGN.md
§3) is per-tick *token budgets*: every scheduler tick the engine

  1. observes per-agent arrivals and queue depths,
  2. runs the allocation policy (Algorithm 1 by default),
  3. grants agent i a compute budget of ``g_i * budget_tokens`` decode
     tokens (prefills are charged their prompt length),
  4. steps each agent's batched prefill/decode within its budget,
  5. records the same metrics as the paper's simulator (latency,
     throughput, allocation, queue length, cost),
  6. with a ``Workflow`` (``core/routing.py``): routes each *finished*
     request to its downstream runtimes — the generated tokens become the
     child request's prompt, fractional routing weights accumulate as
     credit and spawn whole child requests deterministically, and the
     children count as next-tick arrivals, exactly like the simulator's
     endogenous-arrival path,
  7. with a ``CapacityConfig`` (``core/capacity.py``): runs the warm-pool
     autoscaler each tick *before* the allocation policy — the tick's token
     budget is ``warm(t) · budget_tokens`` (``budget_tokens`` is per
     instance), so a scaled-to-zero pool decodes nothing and a cold-starting
     pool stalls exactly as in the simulator; billing is warm-instance-ticks
     through the same ``billing_cost`` helper.

Runs end-to-end on CPU with reduced configs (examples/serve_fleet.py) —
the same engine the production launcher would drive per pod.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocator as alloc
from repro.core import capacity as cap_mod
from repro.core import failures as fail_mod
from repro.core.agents import Fleet, T4_PRICE_PER_HOUR
from repro.core.capacity import CapacityConfig, billing_cost
from repro.core.failures import FailureSpec
from repro.core.routing import Workflow, check_workflow
from repro.models.model import ModelApi


@dataclasses.dataclass
class Request:
    agent: str
    prompt: np.ndarray           # (prompt_len,) int32
    max_new_tokens: int
    arrival_tick: int
    id: int = -1
    tokens_out: list = dataclasses.field(default_factory=list)
    finish_tick: int = -1
    parent_id: int = -1          # upstream request that spawned this one
    retries: int = 0             # deadline retries consumed so far


@dataclasses.dataclass
class AgentRuntime:
    """One model + its queue + fixed decode batch slots."""

    name: str
    api: ModelApi
    params: object
    max_len: int
    batch_slots: int
    queue: deque = dataclasses.field(default_factory=deque)
    active: list = dataclasses.field(default_factory=list)  # per-slot Request|None
    caches: object = None
    pos: np.ndarray | None = None            # per-slot next position
    _decode_jit: Callable | None = None

    def __post_init__(self):
        self.active = [None] * self.batch_slots
        self.pos = np.zeros(self.batch_slots, np.int64)

    def free_slots(self):
        return [i for i, r in enumerate(self.active) if r is None]


def _pad_to(x, n, fill=0):
    return np.concatenate([x, np.full(n - len(x), fill, x.dtype)])


class FleetEngine:
    def __init__(
        self,
        fleet: Fleet,
        runtimes: dict[str, AgentRuntime],
        policy: str = "adaptive",
        budget_tokens: int = 64,
        g_total: float = 1.0,
        ema_alpha: float = 0.3,
        workflow: Workflow | None = None,
        capacity: CapacityConfig | None = None,
        num_gpus: float = 1.0,
        price_per_hour: float = T4_PRICE_PER_HOUR,
        failures: FailureSpec | None = None,
    ):
        assert set(fleet.names) == set(runtimes)
        alloc.get_policy(policy)  # fail fast on unregistered policies
        if workflow is not None:
            check_workflow(workflow, fleet.num_agents)
        if capacity is not None:
            cap_mod.check_capacity(capacity, g_total, num_gpus)
        else:
            cap_mod.check_budget_ceiling(g_total, num_gpus)
        failures = fail_mod.resolve_failures(failures)
        if failures is not None:
            if failures.batched:
                raise ValueError(
                    "FleetEngine takes a single FailureSpec; stacked specs "
                    "only flow through sweep(..., failures=[...])"
                )
            fail_mod.check_failures(failures)
        self.fleet = fleet
        self.runtimes = [runtimes[n] for n in fleet.names]
        self.policy = policy
        self.ema_alpha = ema_alpha
        self.budget_tokens = budget_tokens
        self.g_total = g_total
        self.workflow = workflow
        self.capacity = capacity
        self.num_gpus = num_gpus
        self.price_per_hour = price_per_hour
        self.failures = failures
        # Failure-chain state + counters (same chains as the simulator:
        # ``failure_uniforms`` is counter-based in the tick, so an engine
        # run and a simulator run on the same spec see identical draws).
        self._rev_on = 0.0
        self._down = np.zeros(fleet.num_agents)
        self.dropped = 0
        self.retried = 0
        self.slo_violations = 0
        self._deadline = (
            None if failures is None else
            np.broadcast_to(
                np.asarray(failures.deadline_s, np.float64),
                (fleet.num_agents,),
            ).copy()
        )
        # Warm-pool state: the same eager ``capacity_step`` the simulator
        # scans over, so engine and simulator cannot drift.
        self._cap_state = cap_mod.init_capacity_state(g_total)
        self.tick = 0
        self._next_id = 0
        self._arrivals_this_tick = np.zeros(fleet.num_agents)
        self._ema = np.zeros(fleet.num_agents)
        self._ema_seeded = False
        # Fractional routing credit per (upstream, downstream) pair: whole
        # child requests spawn when a cell accumulates >= 1.  The routed
        # weight per finished request is fixed, so it is materialized on
        # the host once rather than per tick.
        self._route_credit = np.zeros((fleet.num_agents, fleet.num_agents))
        self._route_weights = (
            None if workflow is None else
            np.asarray(workflow.route, np.float64)
            * np.asarray(workflow.fan_out, np.float64)[:, None]
        )
        self._source_flags = (
            None if workflow is None else np.asarray(workflow.source, np.float64)
        )
        self.history: list[dict] = []
        self.completed: list[Request] = []

    # -- request intake ------------------------------------------------------

    def submit(self, agent: str, prompt: np.ndarray, max_new_tokens: int,
               parent_id: int = -1):
        idx = self.fleet.names.index(agent)
        # Same contract as the simulator, which zeroes exogenous arrivals at
        # non-source agents: outside traffic may only enter at sources.
        # Routed children (parent_id >= 0) are the endogenous path and land
        # wherever the matrix sends them.
        if (self.workflow is not None and parent_id < 0
                and self._source_flags[idx] == 0.0):
            raise ValueError(
                f"agent {agent!r} is not a source of workflow "
                f"{self.workflow.name!r}; exogenous requests may only enter "
                "at source agents"
            )
        # Routed children are submitted while tick T is still being served
        # but only become servable (and are counted in lam) at T+1 — stamp
        # them with their effective arrival, matching the simulator's
        # endogenous-arrival-at-t+1 semantics.
        arrival = self.tick + 1 if parent_id >= 0 else self.tick
        req = Request(agent, np.asarray(prompt, np.int32), max_new_tokens, arrival,
                      id=self._next_id, parent_id=parent_id)
        self._next_id += 1
        self.runtimes[idx].queue.append(req)
        self._arrivals_this_tick[idx] += 1
        return req

    # -- allocation ----------------------------------------------------------

    def _forecast(self, lam: np.ndarray) -> jnp.ndarray:
        """Same EMA semantics as the simulator's scan: seed with the first
        observation, update thereafter — at the first tick the policy sees
        lam_ema == lam instead of a drifted zero-seeded forecast."""
        lam_j = jnp.asarray(lam, jnp.float32)
        if not self._ema_seeded:
            ema_j = lam_j
            self._ema_seeded = True
        else:
            ema_j = alloc.ema_forecast(
                jnp.asarray(self._ema, jnp.float32), lam_j, self.ema_alpha
            )
        self._ema = np.asarray(ema_j)
        return ema_j

    def _capacity_tick(
        self, lam_tot: float, ema_tot: float, queue_tot: float
    ) -> tuple[float, float]:
        """One warm-pool autoscaler update; returns (warm, pending).  The
        simulator's exact ``capacity_step``, run eagerly per tick."""
        f32 = lambda x: jnp.asarray(x, jnp.float32)
        self._cap_state, warm, pending = cap_mod.capacity_step(
            self._cap_state, self.capacity, jnp.asarray(self.tick),
            f32(lam_tot), f32(ema_tot), f32(queue_tot),
            self.g_total, self.num_gpus,
        )
        return float(warm), float(pending)

    def _allocate(
        self, lam: np.ndarray, queues: np.ndarray, ema_j: jnp.ndarray,
        g_total_t: float,
    ) -> np.ndarray:
        t = jnp.asarray(self.tick)
        lam_j, q_j = jnp.asarray(lam, jnp.float32), jnp.asarray(queues, jnp.float32)
        g = alloc.dispatch(self.policy, t, lam_j, ema_j, q_j, self.fleet, g_total_t)
        return np.asarray(g)

    # -- failure injection ---------------------------------------------------

    def _failure_tick(self) -> tuple[float, np.ndarray]:
        """Advance the revocation/outage chains for this tick.

        Returns ``(phi, up)``: the fraction of warm capacity revoked and
        the per-agent availability gate.  Also claws revoked instances
        out of the warm-pool state so an elastic autoscaler must
        re-provision them through its cold-start pipeline — the engine
        analogue of the simulator's post-step ``warm *= (1 - phi)``.
        """
        if self.failures is None:
            return 0.0, np.ones(self.fleet.num_agents)
        u_rev, u_down = fail_mod.failure_uniforms(
            self.failures, self.tick, self.fleet.num_agents
        )
        phi, up, rev_nxt, down_nxt = fail_mod.advance_failures(
            self.failures, self.tick, self._rev_on, self._down, u_rev, u_down
        )
        self._rev_on = float(rev_nxt)
        self._down = np.asarray(down_nxt, np.float64)
        phi = float(phi)
        if phi > 0.0 and self.capacity is not None:
            st = self._cap_state
            self._cap_state = cap_mod.CapacityState(
                st.warm * (1.0 - phi), st.pipeline, st.idle_s
            )
        return phi, np.asarray(up, np.float64)

    def _enforce_deadlines(self):
        """Retry or drop queued requests whose sojourn exceeds the deadline.

        A request waiting longer than its agent's ``deadline_s`` (ticks)
        violates its SLO: while it has retry budget left it re-enters the
        back of the queue with a fresh arrival stamp, afterwards it is
        dropped.  In-service (admitted) requests are past queueing and are
        never expired — matching the fluid model, where only backlog mass
        is subject to the deadline.
        """
        if self.failures is None:
            return
        budget = int(np.clip(
            float(np.asarray(self.failures.retry_budget)),
            0, fail_mod.RETRY_CLASSES - 1,
        ))
        for i, rt in enumerate(self.runtimes):
            deadline = self._deadline[i]
            if deadline <= 0 or not rt.queue:
                continue
            survivors = deque()
            while rt.queue:
                req = rt.queue.popleft()
                if self.tick - req.arrival_tick <= deadline:
                    survivors.append(req)
                    continue
                self.slo_violations += 1
                if req.retries < budget:
                    req.retries += 1
                    req.arrival_tick = self.tick
                    survivors.append(req)
                    self.retried += 1
                else:
                    self.dropped += 1
            rt.queue = survivors

    # -- workflow routing ----------------------------------------------------

    def _route_finished(self, finished: list[Request]) -> int:
        """Fan finished requests out to downstream runtimes.

        Each finished request at agent i adds ``route[i] * fan_out[i]`` to
        the per-edge credit; every whole unit of credit spawns one child
        request (prompt = the parent's generated tokens) via ``submit``, so
        children are counted as next-tick arrivals — the engine analogue of
        the simulator's ``arrivals_endogenous = (served * fan_out) @ route``.
        """
        if self.workflow is None or not finished:
            return 0
        spawned = 0
        for req in finished:
            i = self.fleet.names.index(req.agent)
            self._route_credit[i] += self._route_weights[i]
            for j in np.nonzero(self._route_credit[i] >= 1.0)[0]:
                k = int(self._route_credit[i, j])
                self._route_credit[i, j] -= k
                prompt = np.asarray(req.tokens_out, np.int32)
                if prompt.size == 0:
                    prompt = req.prompt
                for _ in range(k):
                    self.submit(self.fleet.names[j], prompt,
                                req.max_new_tokens, parent_id=req.id)
                    spawned += 1
        return spawned

    # -- model stepping ------------------------------------------------------

    def _admit(self, rt: AgentRuntime, budget: int) -> int:
        """Prefill queued requests into free slots; returns tokens spent."""
        spent = 0
        while rt.queue and rt.free_slots():
            req = rt.queue[0]
            cost = len(req.prompt)
            if spent + cost > budget:
                break
            rt.queue.popleft()
            slot = rt.free_slots()[0]
            self._prefill_into_slot(rt, slot, req)
            spent += cost
        return spent

    def _prefill_into_slot(self, rt: AgentRuntime, slot: int, req: Request):
        cfg = rt.api.cfg
        s = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if cfg.frontend == "vision":
            fe = min(cfg.frontend_tokens, s)
            batch["frontend_embeds"] = jnp.zeros((1, fe, cfg.d_model), jnp.bfloat16)
        if cfg.arch_type == "encdec":
            batch["frontend_embeds"] = jnp.zeros((1, 64, cfg.d_model), jnp.bfloat16)
        logits, caches1 = rt.api.prefill(rt.params, batch, rt.max_len)
        tok = int(jnp.argmax(logits[0]))
        req.tokens_out.append(tok)
        if rt.caches is None:
            rt.caches = self._empty_caches(rt)
        rt.caches = _scatter_slot(rt.caches, caches1, slot)
        rt.active[slot] = req
        rt.pos[slot] = s

    def _empty_caches(self, rt: AgentRuntime):
        from repro.models.params import init_params

        decls = rt.api.cache_decls(rt.batch_slots, rt.max_len)
        return init_params(decls, jax.random.key(0), dtype=jnp.bfloat16)

    def _decode_once(self, rt: AgentRuntime) -> int:
        """One batched decode step over occupied slots; returns tokens made."""
        occupied = [i for i, r in enumerate(rt.active) if r is not None]
        if not occupied:
            return 0
        tokens = np.zeros(rt.batch_slots, np.int32)
        for i in occupied:
            tokens[i] = rt.active[i].tokens_out[-1]
        pos = int(max(rt.pos[i] for i in occupied))
        if rt._decode_jit is None:
            ml = rt.max_len
            rt._decode_jit = jax.jit(
                lambda p, c, t, pp: rt.api.decode_step(p, c, t, pp, ml)
            )
        logits, rt.caches = rt._decode_jit(
            rt.params, rt.caches, jnp.asarray(tokens), jnp.int32(pos)
        )
        made = 0
        lg = np.asarray(jax.device_get(logits))
        for i in occupied:
            req = rt.active[i]
            req.tokens_out.append(int(lg[i].argmax()))
            rt.pos[i] += 1
            made += 1
            if len(req.tokens_out) >= req.max_new_tokens or rt.pos[i] >= rt.max_len - 1:
                req.finish_tick = self.tick
                self.completed.append(req)
                rt.active[i] = None
        return made

    # -- main loop -----------------------------------------------------------

    def step(self):
        self._enforce_deadlines()
        lam = self._arrivals_this_tick.copy()
        self._arrivals_this_tick[:] = 0.0
        queues = np.array(
            [len(rt.queue) + sum(r is not None for r in rt.active) for rt in self.runtimes],
            np.float32,
        )
        ema_j = self._forecast(lam)
        if self.capacity is not None:
            warm, pending = self._capacity_tick(
                float(lam.sum()), float(np.asarray(ema_j).sum()),
                float(queues.sum()),
            )
        else:
            warm, pending = self.g_total, 0.0
        phi, up = self._failure_tick()
        # Revoked capacity gates the tick's token budget exactly like the
        # simulator's g_eff = g · up with cap_eff scaled by (1 - phi).
        warm_eff = warm * (1.0 - phi)
        g = self._allocate(lam, queues, ema_j, warm_eff)
        served = np.zeros(len(self.runtimes))
        done_before = len(self.completed)
        for i, rt in enumerate(self.runtimes):
            if up[i] < 0.5:
                # Agent outage: queue (and in-flight slots) preserved,
                # nothing admitted or decoded this tick.
                continue
            # g sums to at most the warm pool, so the fleet-wide spend is
            # capped at warm · budget_tokens: the warm pool gates the
            # token budget.
            budget = int(round(g[i] * self.budget_tokens))
            spent = self._admit(rt, budget)
            while spent < budget:
                made = self._decode_once(rt)
                if made == 0:
                    break
                spent += made
                served[i] += made
        # Requests that finished this tick flow downstream; their children
        # land in _arrivals_this_tick, i.e. they arrive at tick+1.
        routed = self._route_finished(self.completed[done_before:])
        self.history.append(
            {"tick": self.tick, "allocation": g.tolist(), "arrivals": lam.tolist(),
             "queues": queues.tolist(), "decode_tokens": served.tolist(),
             "routed": routed, "warm": warm, "pending": pending,
             "revoked_frac": phi, "down": (up < 0.5).sum().item()}
        )
        self.tick += 1

    # -- metrics (same definitions as the paper simulator) --------------------

    def metrics(self) -> dict:
        lat = [r.finish_tick - r.arrival_tick for r in self.completed]
        per_agent = {}
        for n in self.fleet.names:
            ls = [r.finish_tick - r.arrival_tick for r in self.completed if r.agent == n]
            per_agent[n] = float(np.mean(ls)) if ls else float("nan")
        toks = sum(len(r.tokens_out) for r in self.completed)
        warm_ticks = sum(h["warm"] for h in self.history)
        out = {
            "completed": len(self.completed),
            "avg_latency_ticks": float(np.mean(lat)) if lat else float("nan"),
            "per_agent_latency": per_agent,
            "tokens_generated": toks,
            "throughput_tokens_per_tick": toks / max(self.tick, 1),
            "mean_allocation": np.mean(
                [h["allocation"] for h in self.history], axis=0
            ).tolist() if self.history else [],
            # Billing: one tick = one second of warm capacity.
            "warm_instance_ticks": float(warm_ticks),
            "mean_warm_instances": (
                float(warm_ticks / len(self.history)) if self.history else 0.0
            ),
            "cost_usd": float(billing_cost(warm_ticks, self.price_per_hour)),
            # Failure accounting (zeros when failures=None — the counters
            # exist unconditionally so dashboards need no schema branch).
            "dropped": self.dropped,
            "retried": self.retried,
            "slo_violations": self.slo_violations,
        }
        if self.workflow is not None:
            # End-to-end view: a request finishing at a sink closes the
            # whole workflow chain that began at its root submission.
            sink = np.asarray(self.workflow.sink)
            by_id = {r.id: r for r in self.completed}

            def root(req: Request) -> Request:
                while req.parent_id >= 0 and req.parent_id in by_id:
                    req = by_id[req.parent_id]
                return req

            done = [
                r for r in self.completed
                if sink[self.fleet.names.index(r.agent)] > 0
            ]
            e2e = [r.finish_tick - root(r).arrival_tick for r in done]
            out["sink_completed"] = len(done)
            out["end_to_end_latency_ticks"] = (
                float(np.mean(e2e)) if e2e else float("nan")
            )
            out["routed_requests"] = sum(h.get("routed", 0) for h in self.history)
        return out


def _scatter_slot(caches, caches1, slot: int):
    """Write a batch-1 cache tree into slot `slot` of the batched cache."""

    def upd(full, one):
        # Caches carry batch in dim 0 (transformer) or dim 1 (stacked layers).
        if full.ndim == one.ndim and one.shape[0] == 1 and full.shape[0] != 1:
            return full.at[slot].set(one[0].astype(full.dtype))
        if full.ndim == one.ndim and one.shape[1] == 1:
            return full.at[:, slot].set(one[:, 0].astype(full.dtype))
        raise ValueError((full.shape, one.shape))

    return jax.tree_util.tree_map(upd, caches, caches1)
