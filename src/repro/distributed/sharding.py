"""Logical-axis -> mesh-axis sharding rules.

Model code declares *logical* axes on every parameter / cache dimension
(see repro.models.params).  This module maps them onto the production mesh:

Training rules (2D: FSDP over "data", tensor over "model"; "pod" is pure
data parallelism):
    batch    -> (pod, data)      activations
    embed    -> data             d_model rows of weights   (FSDP / ZeRO-3)
    ffn/heads/kv/vocab -> model  weight output dims        (tensor parallel)
    experts  -> None             (per-expert dims already sharded)
    layers   -> None             (scan axis)

Serving rules differ on the caches: the KV-cache sequence dim shards over
"model" (sequence-sharded decode attention — GSPMD turns the softmax and
PV contraction into all-reduces), keeping a 405B 32k cache within HBM.

Any dimension not divisible by its mapped axis size is replicated instead
(recorded by ``explain_specs`` so the dry-run log shows every fallback).
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamDecl, is_decl, tree_map_decls

TRAIN_RULES: dict[str, tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "cache_batch": ("pod", "data"),
    "embed": ("data",),
    "ffn": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": None,
    "layers": None,
    "kv_seq": None,
}

SERVE_RULES: dict[str, tuple[str, ...] | None] = {
    **TRAIN_RULES,
    "kv_seq": ("model",),   # sequence-sharded KV cache
    "kv_heads": None,       # kv heads (1-16) rarely divide the model axis
}

# Beyond-baseline serving rules (§Perf hillclimb): decode activations are
# REPLICATED over the data axis instead of batch-sharded.  With 2D-sharded
# weights (embed->data, ffn/heads->model) GSPMD then partial-sums the
# data-axis contraction and all-reduces small (B, out) activations instead
# of all-gathering ~GBs of weights every token.  Caches stay batch-sharded
# on data ("cache_batch"), seq-sharded on model.
SERVE_V2_RULES: dict[str, tuple[str, ...] | None] = {
    **SERVE_RULES,
    "batch": None,
}

# Expert-parallel variants (§Perf): expert dim shards over "model"; the
# per-expert FFN dim falls back to replicated (axis reuse), so expert
# weights live E/16 per device and dispatch/combine become all-to-alls.
SERVE_EP_RULES = {**SERVE_RULES, "experts": ("model",)}
SERVE_V2_EP_RULES = {**SERVE_V2_RULES, "experts": ("model",)}
TRAIN_EP_RULES = {**TRAIN_RULES, "experts": ("model",)}

# Mixtral-class caches are window-sized (4k) — small enough to skip
# sequence sharding and its distributed-softmax all-reduces.
SERVE_V2_NOSEQ_RULES = {**SERVE_V2_RULES, "kv_seq": None}

# v3 (§Perf iter 3): the new token's k/v must be broadcast into the
# model-(seq-)sharded cache anyway, so sharding the kv projection's output
# dim on "model" makes GSPMD all-gather w_k/w_v (67 MB/layer/token for
# 405B) on every decode step.  Replicate that dim (rows stay data-sharded:
# +2.1 MB/layer/device for 405B) and the gather disappears.
SERVE_V3_RULES = {**SERVE_V2_RULES, "kv": None}

# Sequence-parallel activations (§Perf pair 4): when num_heads does not
# divide the model axis (minitron 24H, qwen2-vl 12H on a 16-way axis),
# head-sharded attention degenerates into partially-replicated tilings
# whose repair is an all-reduce of the full (S,S) logits.  Sharding the
# activation SEQUENCE dim over "model" instead sidesteps head sharding:
# attention gathers K/V once (B·S·kv·hd, ~134 MB for minitron-32k) and all
# S² work stays local.  Applied to dim 1 of model inputs by
# ``batch_shardings`` via the "seq" rule.
SERVE_SP_RULES = {**SERVE_RULES, "seq": ("model",)}
TRAIN_SP_RULES = {**TRAIN_RULES, "seq": ("model",)}

RULE_SETS = {
    "train": TRAIN_RULES,
    "train_ep": TRAIN_EP_RULES,
    "serve": SERVE_RULES,
    "serve_ep": SERVE_EP_RULES,
    "serve_v2": SERVE_V2_RULES,
    "serve_v2_ep": SERVE_V2_EP_RULES,
    "serve_v2_noseq": SERVE_V2_NOSEQ_RULES,
    "serve_v3": SERVE_V3_RULES,
    "serve_sp": SERVE_SP_RULES,
    "train_sp": TRAIN_SP_RULES,
}


def _mesh_axes(mesh: Mesh, wanted: tuple[str, ...] | None) -> tuple[str, ...]:
    if wanted is None:
        return ()
    return tuple(a for a in wanted if a in mesh.shape)


def spec_for_axes(
    mesh: Mesh,
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    rules: dict[str, tuple[str, ...] | None],
) -> P:
    """PartitionSpec for one array; replicates non-divisible dims."""
    used: set[str] = set()
    entries: list[Any] = []
    for dim, logical in zip(shape, axes):
        if logical is None or logical not in rules:
            entries.append(None)
            continue
        mapped = tuple(a for a in _mesh_axes(mesh, rules[logical]) if a not in used)
        total = math.prod(mesh.shape[a] for a in mapped) if mapped else 1
        if not mapped or dim % total != 0:
            entries.append(None)
            continue
        used.update(mapped)
        entries.append(mapped if len(mapped) > 1 else mapped[0])
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shardings_for_decls(mesh: Mesh, decl_tree, rules=TRAIN_RULES):
    """NamedSharding tree matching a ParamDecl tree."""
    return tree_map_decls(
        lambda d: NamedSharding(mesh, spec_for_axes(mesh, d.shape, d.axes, rules)),
        decl_tree,
    )


def batch_shardings(mesh: Mesh, specs: dict, rules=TRAIN_RULES):
    """Shardings for an input_specs dict: dim0 = batch; dim1 = sequence iff
    the rule set enables sequence parallelism ("seq"); rest replicated.

    positions3 / frontend_embeds / tokens all carry (batch, seq, ...) first.
    """
    out = {}
    for k, sds in specs.items():
        entries: list = []
        bdims = _mesh_axes(mesh, rules["batch"])
        total = math.prod(mesh.shape[a] for a in bdims) if bdims else 1
        if bdims and sds.shape and sds.shape[0] % total == 0:
            entries.append(bdims if len(bdims) > 1 else bdims[0])
        else:
            entries.append(None)
        sdims = _mesh_axes(mesh, rules.get("seq"))
        stotal = math.prod(mesh.shape[a] for a in sdims) if sdims else 1
        if sdims and len(sds.shape) >= 2 and sds.shape[1] % stotal == 0:
            entries.append(sdims if len(sdims) > 1 else sdims[0])
        while entries and entries[-1] is None:
            entries.pop()
        out[k] = NamedSharding(mesh, P(*entries))
    return out


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def explain_specs(mesh: Mesh, decl_tree, rules=TRAIN_RULES) -> list[str]:
    """Human-readable fallback report for the dry-run log."""
    lines: list[str] = []

    def visit(path, d: ParamDecl):
        spec = spec_for_axes(mesh, d.shape, d.axes, rules)
        wanted = [a for a in d.axes if a and rules.get(a)]
        got = [e for e in spec if e is not None]
        if wanted and not got:
            lines.append(f"{path}: {d.shape} axes={d.axes} -> replicated (non-divisible)")
        return d

    flat = jax.tree_util.tree_flatten_with_path(decl_tree, is_leaf=is_decl)[0]
    for path, d in flat:
        visit(jax.tree_util.keystr(path), d)
    return lines
