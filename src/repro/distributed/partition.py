"""Spatial mesh partitioning: the integer-chip analogue of Algorithm 1.

DESIGN.md §3: the primary TPU reading of fractional GPU allocation is
time-multiplexed token budgets (serving/engine.py).  This module is the
documented alternative — carve a pod's `model`-axis chips into per-agent
sub-meshes using the same demand → max(min, proportional) → renormalize
structure, with integer rounding by largest remainder (Hamilton method)
so Σ chips == total exactly and every busy agent keeps its minimum.

Spatial re-partitioning costs a weight reshard (seconds, not the paper's
milliseconds) — the planner therefore exposes `stability_gain`: how much
the new plan must improve projected throughput before a reshard is worth
it.  This deviation from the paper is recorded in DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    chips: tuple[int, ...]           # per-agent chip counts, sums to total
    fractions: tuple[float, ...]     # continuous allocation it rounds
    total_chips: int


def plan_partition(
    lam: np.ndarray,
    min_gpu: np.ndarray,
    priority: np.ndarray,
    total_chips: int,
) -> PartitionPlan:
    """Algorithm 1 + largest-remainder integer rounding over chips."""
    lam = np.asarray(lam, np.float64)
    min_gpu = np.asarray(min_gpu, np.float64)
    priority = np.asarray(priority, np.float64)
    busy_in = lam > 0
    demand = np.where(busy_in, np.maximum(lam * min_gpu / priority, 1e-300), 0.0)
    d_total = demand.sum()
    if d_total <= 0:
        return PartitionPlan((0,) * len(lam), (0.0,) * len(lam), total_chips)
    g = np.maximum(min_gpu, demand / d_total)
    g = np.where(lam > 0, g, np.minimum(g, min_gpu))
    if g.sum() > 1.0:
        g = g / g.sum()

    busy = lam > 0
    if int(busy.sum()) > total_chips:
        # Degenerate: more busy agents than chips — one chip each to the
        # highest-demand agents; the rest wait (time-multiplexed instead).
        chips = np.zeros(len(lam), int)
        order = np.argsort(-demand)
        chips[order[:total_chips]] = 1
        return PartitionPlan(tuple(int(c) for c in chips),
                             tuple(float(x) for x in g), total_chips)

    raw = g * total_chips
    floor = np.floor(raw).astype(int)
    # Guarantee >=1 chip for any busy agent before distributing remainders.
    floor = np.where(busy & (floor == 0), 1, floor)
    deficit = total_chips - floor.sum()
    if deficit < 0:  # minimum-guarantee overshoot: take from largest
        order = np.argsort(-floor)
        for i in order:
            while deficit < 0 and floor[i] > 1:
                floor[i] -= 1
                deficit += 1
    rema = raw - np.floor(raw)
    order = np.argsort(-rema)
    for i in order:
        if deficit == 0:
            break
        floor[i] += 1
        deficit -= 1
    return PartitionPlan(tuple(int(c) for c in floor), tuple(float(x) for x in g),
                         total_chips)


def should_repartition(
    current: PartitionPlan,
    proposed: PartitionPlan,
    base_throughput: np.ndarray,
    stability_gain: float = 0.10,
) -> bool:
    """Reshard only if projected capacity improves by > stability_gain."""
    t = np.asarray(base_throughput, np.float64)
    cur = (np.asarray(current.chips) / current.total_chips * t).sum()
    new = (np.asarray(proposed.chips) / proposed.total_chips * t).sum()
    if cur <= 0:
        return new > 0
    return (new - cur) / cur > stability_gain
