"""Profile derivation from recorded dry-run artifacts (skipped if absent)."""
import os

import pytest

from repro.core.profiles import available_archs, fleet_from_archs, profile_arch

pytestmark = pytest.mark.skipif(
    not os.path.exists("experiments/roofline"),
    reason="no roofline artifacts; run repro.launch.roofline",
)


def test_profiles_exist_for_all_decode_archs():
    archs = available_archs()
    assert len(archs) >= 5
    for a in archs:
        p = profile_arch(a)
        assert p["throughput_tokens_per_s"] > 0
        assert 0.0 < p["min_gpu"] <= 0.9
        assert p["model_mb"] > 0


def test_bigger_models_are_slower():
    small = profile_arch("qwen2-vl-2b")
    big = profile_arch("llama3-405b")
    if small and big:
        assert small["throughput_tokens_per_s"] > big["throughput_tokens_per_s"]
        assert small["min_gpu"] < big["min_gpu"]


def test_fleet_builds_and_validates():
    archs = available_archs()[:3]
    fleet = fleet_from_archs({a: 1 + i % 2 for i, a in enumerate(archs)})
    fleet.validate()
    assert fleet.num_agents == len(archs)
