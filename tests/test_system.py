"""End-to-end behaviour tests for the paper's system: the adaptive
allocator beats round-robin on latency at equal cost when driving REAL
models through the serving engine (the paper's Table II claim, verified on
the integrated stack rather than the simulator)."""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.agents import AgentSpec, Fleet
from repro.models.model import build_model
from repro.serving.engine import AgentRuntime, FleetEngine


def _run(policy: str, ticks: int = 16):
    fleet = Fleet.from_specs([
        AgentSpec("coordinator", 100.0, 100.0, 0.10, 1),
        AgentSpec("specialist", 500.0, 30.0, 0.35, 1),
    ])
    key = jax.random.key(0)
    rts = {}
    for name, arch in (("coordinator", "qwen2-vl-2b"), ("specialist", "granite-8b")):
        cfg = get_config(arch, reduced=True)
        api = build_model(cfg)
        rts[name] = AgentRuntime(name, api, api.init(key), max_len=48, batch_slots=2)
    eng = FleetEngine(fleet, rts, policy=policy, budget_tokens=24)
    rng = np.random.default_rng(0)
    for t in range(ticks):
        eng.submit("coordinator", rng.integers(0, 100, 4), 2)
        if t % 2 == 0:
            eng.submit("specialist", rng.integers(0, 100, 4), 2)
        eng.step()
    return eng.metrics()


def test_adaptive_beats_round_robin_on_latency():
    a = _run("adaptive")
    r = _run("round_robin")
    assert a["completed"] >= r["completed"]
    assert a["avg_latency_ticks"] <= r["avg_latency_ticks"] + 1e-9


def test_adaptive_comparable_throughput_to_static():
    a = _run("adaptive")
    s = _run("static_equal")
    assert a["tokens_generated"] >= 0.8 * s["tokens_generated"]
