"""Per-architecture smoke tests (reduced configs) + serving consistency.

Each assigned architecture instantiates its REDUCED family variant, runs a
train step and a prefill->decode chain on CPU, and asserts shapes + no
NaNs + decode/prefill agreement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import (
    INPUT_SHAPES, InputShape, build_model, concrete_inputs, shape_applicable,
)
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import build_train_step

KEY = jax.random.key(0)
SMALL_TRAIN = InputShape("train_small", 32, 2, "train")
SMALL_PREFILL = InputShape("prefill_small", 16, 2, "prefill")


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


class TestSmoke:
    def test_train_step(self, arch):
        cfg = get_config(arch, reduced=True)
        api = build_model(cfg)
        params = api.init(KEY)
        batch = concrete_inputs(cfg, SMALL_TRAIN, KEY)
        step = build_train_step(api, OptimizerConfig(warmup_steps=1, total_steps=10))
        from repro.training.optimizer import init_opt_state

        opt = init_opt_state(params)
        params2, opt2, metrics = jax.jit(step)(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert int(opt2["step"]) == 1
        # parameters actually moved
        moved = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
            params, params2,
        )
        assert max(jax.tree_util.tree_leaves(moved)) > 0

    def test_logits_shape_and_finite(self, arch):
        cfg = get_config(arch, reduced=True)
        api = build_model(cfg)
        params = api.init(KEY)
        batch = concrete_inputs(cfg, SMALL_PREFILL, KEY)
        logits, caches = api.prefill(params, batch, SMALL_PREFILL.seq_len + 4)
        assert logits.shape == (2, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def test_decode_matches_prefill(self, arch):
        cfg = get_config(arch, reduced=True)
        api = build_model(cfg)
        params = api.init(KEY, dtype=jnp.float32)
        s, extra = 16, 4
        batch = concrete_inputs(cfg, SMALL_PREFILL, KEY, dtype=jnp.float32)
        max_len = s + extra
        _, caches = api.prefill(params, batch, max_len)
        toks = jax.random.randint(jax.random.key(2), (2, extra), 0, cfg.vocab_size)
        last = None
        for i in range(extra):
            last, caches = api.decode_step(params, caches, toks[:, i], jnp.int32(s + i), max_len)
        batch2 = dict(batch)
        batch2["tokens"] = jnp.concatenate([batch["tokens"], toks], axis=1)
        if "positions3" in batch2:
            base = jnp.arange(s + extra, dtype=jnp.int32)[None, :, None]
            batch2["positions3"] = jnp.broadcast_to(base, (2, s + extra, 3))
        want, _ = api.prefill(params, batch2, max_len)
        np.testing.assert_allclose(np.asarray(last), np.asarray(want), atol=1e-4)

    def test_full_config_declares(self, arch):
        """FULL configs build decl trees + ShapeDtypeStructs w/o allocation."""
        cfg = get_config(arch)
        api = build_model(cfg)
        sds = api.abstract()
        n = sum(
            np.prod(l.shape) for l in jax.tree_util.tree_leaves(sds)
        )
        assert n > 0.5 * cfg.param_count  # stacked decls cover the model

    def test_shape_applicability_matrix(self, arch):
        cfg = get_config(arch)
        ok_500k, _ = shape_applicable(cfg, INPUT_SHAPES["long_500k"])
        expect = arch in ("mamba2-370m", "recurrentgemma-9b", "mixtral-8x7b")
        assert ok_500k == expect
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(cfg, INPUT_SHAPES[s])[0]


class TestTrainingConvergence:
    def test_loss_decreases_on_synthetic_data(self):
        """granite-8b reduced on the Markov-Zipf pipeline: loss must drop."""
        from repro.data.pipeline import DataConfig, SyntheticTokens

        cfg = get_config("granite-8b", reduced=True)
        api = build_model(cfg)
        params = api.init(KEY, dtype=jnp.float32)
        data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                          global_batch=8, seed=0))
        from repro.training.optimizer import init_opt_state

        step = jax.jit(build_train_step(api, OptimizerConfig(
            lr=3e-3, warmup_steps=2, total_steps=40)))
        opt = init_opt_state(params)
        losses = []
        for i in range(15):
            b = data.batch(i)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.3, losses
