"""Failure-injection layer (PR 10): no-op parity, oracle parity with two
or more active injectors, queue-mass conservation under deadlines/retries,
retry-budget drop accounting, hand-computed recovery time, and the chaos
sweep axis under a forced 8-device host mesh."""
import os
import subprocess
import sys
import tempfile

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import allocator as alloc
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, AgentSpec, Fleet, paper_fleet
from repro.core.failures import (
    FAILURE_ENV,
    failure_scenario_library,
    failure_spec,
)
from repro.core.reference_sim import simulate_numpy
from repro.core.simulator import METRIC_NAMES, SimConfig, simulate
from repro.core.sweep import Scenario, sweep

SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
)

FLEET = paper_fleet()
RATES = jnp.asarray(PAPER_ARRIVAL_RATES)

# Two active injectors minimum: MMPP revocation + deadlines (+ flaky
# agents), the acceptance bar for oracle parity.
CHAOS = failure_spec(
    "chaos",
    revoke_p_enter=0.15, revoke_p_exit=0.4, revoke_frac=0.7,
    fail_p_enter=0.05, fail_p_exit=0.5,
    deadline_s=3.0, retry_budget=1, seed=3,
)


def _scenarios(steps=40):
    return (
        Scenario("constant", workload.constant(RATES, steps)),
        Scenario("overload_3x", workload.scaled(RATES, steps, 3.0)),
    )


class TestNoOp:
    """failures=None and an all-off spec must not perturb the seed physics."""

    @pytest.mark.parametrize("policy", ("adaptive", "throughput_greedy"))
    def test_disabled_spec_matches_none(self, policy):
        arr = workload.poisson(RATES, 50, jax.random.key(0))
        base = simulate(policy, arr, FLEET)
        off = simulate(policy, arr, FLEET, failures=failure_spec("none"))
        for leaf_base, leaf_off in zip(
            jax.tree_util.tree_leaves(base), jax.tree_util.tree_leaves(off)
        ):
            np.testing.assert_allclose(
                np.asarray(leaf_base), np.asarray(leaf_off),
                rtol=1e-5, atol=1e-6,
            )

    def test_sweep_none_row_matches_plain_grid(self):
        scen = _scenarios()
        plain = sweep(FLEET, scen)
        chaos = sweep(FLEET, scen, failures=[failure_spec("none"), CHAOS])
        assert chaos.failure_names == ("none", "chaos")
        none_row = chaos.metrics[chaos.failure_names.index("none")]
        np.testing.assert_allclose(
            none_row, plain.metrics, rtol=1e-5, atol=1e-6
        )
        # and the chaos row genuinely hurts: deadline drops appear.
        chaos_row = chaos.metrics[chaos.failure_names.index("chaos")]
        assert chaos_row[..., METRIC_NAMES.index("dropped")].max() > 0

    def test_env_kill_switch(self, monkeypatch):
        arr = workload.constant(RATES, 30)
        base = simulate("adaptive", arr, FLEET)
        monkeypatch.setenv(FAILURE_ENV, "0")
        killed = simulate("adaptive", arr, FLEET, failures=CHAOS)
        np.testing.assert_array_equal(
            np.asarray(base.served), np.asarray(killed.served)
        )
        assert np.asarray(killed.dropped).sum() == 0


class TestOracleParity:
    """The straight-line float64 oracle replays the exact failure chains."""

    @pytest.mark.parametrize("policy", alloc.policy_names())
    def test_full_registry_under_chaos(self, policy):
        arr = np.asarray(workload.poisson(RATES, 60, jax.random.key(1)))
        tr = simulate(policy, jnp.asarray(arr), FLEET, failures=CHAOS)
        ref = simulate_numpy(policy, arr, FLEET, failures=CHAOS)
        for field in ("served", "queue", "allocation", "dropped", "retried",
                      "expired", "recovery"):
            got = np.asarray(getattr(tr, field))
            want = ref[field]
            scale = max(np.abs(want).max(), 1.0)
            np.testing.assert_allclose(
                got, want, rtol=5e-3, atol=5e-3 * scale,
                err_msg=f"{policy}/{field}",
            )


def _check_conservation(gen: str, seed: int, steps: int) -> None:
    key = jax.random.key(seed)
    if gen == "constant":
        arr = workload.constant(RATES, steps)
    elif gen == "poisson":
        arr = workload.poisson(RATES, steps, key)
    else:
        arr = workload.bursty(RATES, steps, key)
    spec = failure_spec(
        "mix", revoke_p_enter=0.1, revoke_p_exit=0.4, revoke_frac=0.6,
        deadline_s=2.5, retry_budget=2, seed=seed,
    )
    tr = simulate("adaptive", arr, FLEET, failures=spec)
    arrived = float(np.asarray(tr.arrivals).sum())
    served = float(np.asarray(tr.served).sum())
    dropped = float(np.asarray(tr.dropped).sum())
    final_q = float(np.asarray(tr.queue)[-1].sum())
    # Retried mass stays in the queue (one retry class up), so it is
    # already counted; the dead-band snap discards at most 1e-4 mass
    # per agent-step, hence the absolute slack.
    slack = steps * FLEET.num_agents * 1e-4 + 0.05
    np.testing.assert_allclose(
        arrived, served + dropped + final_q, rtol=1e-3, atol=slack
    )


class TestConservation:
    @hypothesis.given(
        gen=st.sampled_from(("constant", "poisson", "bursty")),
        seed=st.integers(0, 2**16),
        steps=st.integers(20, 60),
    )
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_queue_mass_conserved_under_failures(self, gen, seed, steps):
        _check_conservation(gen, seed, steps)

    @pytest.mark.parametrize("gen,seed,steps", (
        ("constant", 0, 30),
        ("poisson", 11, 45),
        ("bursty", 7, 60),
    ))
    def test_queue_mass_conserved_explicit_cases(self, gen, seed, steps):
        # Example-based floor under the property test: runs even where
        # hypothesis is stubbed out (see conftest).
        _check_conservation(gen, seed, steps)


class TestRetryBudget:
    ARR = workload.scaled(RATES, 40, 3.0)  # overload so deadlines bite

    def test_zero_budget_drops_everything_expired(self):
        spec = failure_spec("strict", deadline_s=1.0, retry_budget=0, seed=0)
        tr = simulate("static_equal", self.ARR, FLEET, failures=spec)
        dropped = np.asarray(tr.dropped)
        assert dropped.sum() > 0, "overloaded 1s deadline must drop mass"
        assert np.asarray(tr.retried).sum() == 0
        np.testing.assert_allclose(
            dropped, np.asarray(tr.expired), rtol=1e-5, atol=1e-5
        )

    def test_budget_splits_expired_into_retried_plus_dropped(self):
        spec = failure_spec("lenient", deadline_s=1.0, retry_budget=2, seed=0)
        tr = simulate("static_equal", self.ARR, FLEET, failures=spec)
        retried = np.asarray(tr.retried)
        dropped = np.asarray(tr.dropped)
        assert retried.sum() > 0
        assert dropped.sum() > 0, "budget exhaustion must still drop"
        np.testing.assert_allclose(
            retried + dropped, np.asarray(tr.expired), rtol=1e-4, atol=1e-3
        )


class TestRecovery:
    def test_recovery_time_matches_hand_computation(self):
        # One agent, service capacity 10/step, arrivals 4/step.  A
        # scheduled outage over steps [2, 7) banks 5*4 = 20 backlog above
        # the zero pre-outage watermark.  Recovery drains 10-4 = 6/step:
        # queue after each post-outage step is 14, 8, 2, 0 — four steps
        # with the recovery indicator up (the drain completes during the
        # fourth), then steady state.
        solo = Fleet.from_specs([AgentSpec("solo", 100.0, 10.0, 0.0, 1)])
        arr = workload.constant(jnp.asarray([4.0]), 12)
        spec = failure_spec("outage", outage_start=2, outage_len=5,
                            outage_agent=0, seed=0)
        tr = simulate("static_equal", arr, solo, failures=spec)
        assert float(np.asarray(tr.served)[2:7].sum()) == 0.0
        np.testing.assert_allclose(float(np.asarray(tr.queue)[6, 0]), 20.0)
        assert float(np.asarray(tr.recovery).sum()) == 4.0


_CHILD = """
import numpy as np
import jax
assert jax.device_count() == 8, jax.devices()
import jax.numpy as jnp
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.failures import failure_scenario_library
from repro.core.sweep import Scenario, sweep
rates = jnp.asarray(PAPER_ARRIVAL_RATES)
scen = (Scenario("constant", workload.constant(rates, {steps})),
        Scenario("overload_3x", workload.scaled(rates, {steps}, 3.0)))
res = sweep(paper_fleet(), scen, failures=failure_scenario_library(),
            shard=True)
np.save({out!r}, res.metrics)
"""


@pytest.mark.skipif(
    jax.device_count() >= 2,
    reason="single-device reference; multi-device hosts exercise the "
           "sharded chaos axis in-process via test_sharded_sweep",
)
def test_chaos_axis_under_8_forced_devices():
    """The stacked failure axis must survive mesh sharding: a forced
    8-device child grid matches the single-device reference."""
    steps = 24
    reference = sweep(
        FLEET, _scenarios(steps), failures=failure_scenario_library(),
        shard=False,
    ).metrics
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "metrics.npy")
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD.format(steps=steps, out=out)],
            env=env, capture_output=True, text=True, timeout=600,
        )
        assert proc.returncode == 0, (proc.stdout, proc.stderr)
        sharded = np.load(out)
    np.testing.assert_allclose(sharded, reference, rtol=1e-5, atol=1e-6)
