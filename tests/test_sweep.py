"""Policy-registry + vmapped sweep-grid tests.

Covers the acceptance invariants: registry completeness (every policy
reachable through ``simulate()``), grid shape/dtype, the Σg <= g_total and
g >= 0 capacity invariants across all policies × all scenario generators,
and a Table II smoke check on the paper's constant workload.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import allocator as alloc
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.simulator import SimConfig, run_policy, simulate
from repro.core.sweep import (
    METRIC_NAMES,
    Scenario,
    SweepSummary,
    scenario_library,
    sweep,
)

FLEET = paper_fleet()
RATES = jnp.asarray(PAPER_ARRIVAL_RATES, jnp.float32)


@pytest.fixture(scope="module")
def grid():
    """One full-registry sweep over the standard library (traces kept)."""
    scenarios = scenario_library(PAPER_ARRIVAL_RATES, num_steps=60, seed=0)
    return scenarios, sweep(FLEET, scenarios, keep_traces=True)


class TestRegistry:
    def test_at_least_seven_policies(self):
        assert len(alloc.policy_names()) >= 7

    def test_policy_names_alias_tracks_registry(self):
        assert alloc.POLICY_NAMES == alloc.policy_names()

    def test_ids_are_registry_order(self):
        for i, name in enumerate(alloc.policy_names()):
            assert alloc.policy_id(name) == i

    def test_unknown_policy_raises_with_known_names(self):
        with pytest.raises(ValueError, match="registered policies"):
            alloc.get_policy("nope")

    def test_every_policy_reachable_from_simulator(self):
        arr = workload.constant(RATES, 5)
        for policy in alloc.policy_names():
            tr = simulate(policy, arr, FLEET)
            assert np.isfinite(np.asarray(tr.allocation)).all(), policy

    def test_dispatch_matches_direct_adaptive_call(self):
        lam = RATES
        g_direct = alloc.adaptive_allocation(lam, FLEET.min_gpu, FLEET.priority)
        g_dispatch = alloc.dispatch(
            "adaptive", jnp.asarray(0), lam, lam, jnp.zeros_like(lam), FLEET, 1.0
        )
        np.testing.assert_allclose(np.asarray(g_direct), np.asarray(g_dispatch))


class TestScenarioLibrary:
    def test_library_size_and_shapes(self):
        scenarios = scenario_library(PAPER_ARRIVAL_RATES, num_steps=40, seed=1)
        assert len(scenarios) >= 7
        names = [s.name for s in scenarios]
        assert len(set(names)) == len(names)
        for s in scenarios:
            assert s.arrivals.shape == (40, 4), s.name
            assert s.arrivals.dtype == jnp.float32, s.name
            assert bool((s.arrivals >= 0).all()), s.name

    def test_bursty_is_markov_modulated(self):
        import jax

        arr = np.asarray(workload.bursty(RATES, 200, jax.random.key(3),
                                         on_factor=4.0, off_factor=0.25))
        ratio = arr / np.asarray(RATES)[None, :]
        assert set(np.round(np.unique(ratio), 4)) <= {0.25, 4.0}
        assert (ratio == 4.0).any() and (ratio == 0.25).any()

    def test_correlated_surges_hit_all_agents_together(self):
        import jax

        arr = np.asarray(workload.correlated(RATES, 200, jax.random.key(4)))
        ratio = arr / np.asarray(RATES)[None, :]
        # per-step modulation factor is shared across the fleet
        assert np.allclose(ratio, ratio[:, :1])
        assert (ratio > 1.0).any() and (ratio == 1.0).any()

    def test_generators_deterministic_given_seed(self):
        a = scenario_library(PAPER_ARRIVAL_RATES, num_steps=30, seed=7)
        b = scenario_library(PAPER_ARRIVAL_RATES, num_steps=30, seed=7)
        for sa, sb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(sa.arrivals), np.asarray(sb.arrivals))


class TestSweepGrid:
    def test_grid_shape_and_dtype(self, grid):
        scenarios, res = grid
        P, W = len(alloc.policy_names()), len(scenarios)
        assert res.metrics.shape == (P, W, len(METRIC_NAMES))
        assert res.metrics.dtype == np.float32
        assert np.isfinite(res.metrics).all()
        assert res.per_agent_latency.shape == (P, W, FLEET.num_agents)
        assert res.per_agent_throughput.shape == (P, W, FLEET.num_agents)

    def test_capacity_invariant_all_policies_all_scenarios(self, grid):
        _, res = grid
        g = np.asarray(res.traces.allocation)  # (P, W, S, N)
        assert (g >= -1e-6).all()
        assert (g.sum(axis=-1) <= res.config.g_total + 1e-4).all()
        assert (np.asarray(res.traces.queue) >= -1e-3).all()

    def test_table_rows_cover_the_grid(self, grid):
        scenarios, res = grid
        table = res.table()
        assert len(table.rows) == len(res.policy_names) * len(scenarios)
        assert table.columns[:2] == ("policy", "scenario")
        assert set(METRIC_NAMES) <= set(table.columns)
        csv = table.to_csv_lines()
        assert len(csv) == len(table.rows) + 1

    def test_cells_match_run_policy(self, grid):
        scenarios, res = grid
        arr = scenarios[0].arrivals  # constant
        for policy in res.policy_names:
            got = res.summary(policy, "constant")
            want = run_policy(policy, arr, FLEET)
            assert abs(got.avg_latency - want.avg_latency) < 1e-3, policy
            assert abs(got.total_throughput - want.total_throughput) < 1e-3, policy
            assert abs(got.latency_std - want.latency_std) < 1e-3, policy
            assert abs(got.cost - want.cost) < 1e-9, policy

    def test_policy_subset_sweep(self):
        scen = (Scenario("constant", workload.constant(RATES, 20)),)
        res = sweep(FLEET, scen, policies=("adaptive", "round_robin"))
        assert res.policy_names == ("adaptive", "round_robin")
        assert res.metrics.shape[0] == 2

    def test_table2_smoke_adaptive_beats_round_robin(self):
        scen = (Scenario("constant", workload.constant(RATES, 100)),)
        res = sweep(FLEET, scen)
        adaptive = res.summary("adaptive", "constant")
        rr = res.summary("round_robin", "constant")
        assert adaptive.avg_latency < rr.avg_latency
        # the paper's headline: ~85% latency reduction at equal cost
        assert 1 - adaptive.avg_latency / rr.avg_latency > 0.84
        assert abs(adaptive.cost - rr.cost) < 1e-9


class TestBestTieHandling:
    """``SweepSummary.best`` must be strict and tie-stable: on an exact tie
    the earliest row (policy-registry order) keeps the win, in both the
    minimize and maximize directions."""

    COLS = ("policy", "scenario", "score")

    def _table(self, rows):
        return SweepSummary(columns=self.COLS, rows=tuple(rows))

    def test_minimize_prefers_strictly_smaller(self):
        t = self._table([("a", "s", 3.0), ("b", "s", 1.0), ("c", "s", 2.0)])
        assert t.best("score", minimize=True) == {"s": "b"}

    def test_maximize_prefers_strictly_larger(self):
        t = self._table([("a", "s", 1.0), ("b", "s", 3.0), ("c", "s", 2.0)])
        assert t.best("score", minimize=False) == {"s": "b"}

    def test_minimize_tie_keeps_first_row(self):
        t = self._table([("a", "s", 1.0), ("b", "s", 1.0), ("c", "s", 2.0)])
        assert t.best("score", minimize=True) == {"s": "a"}

    def test_maximize_tie_keeps_first_row(self):
        t = self._table([("a", "s", 2.0), ("b", "s", 2.0), ("c", "s", 1.0)])
        assert t.best("score", minimize=False) == {"s": "a"}

    def test_all_tied_keeps_first_row_both_directions(self):
        t = self._table([("a", "s", 5.0), ("b", "s", 5.0), ("c", "s", 5.0)])
        assert t.best("score", minimize=True) == {"s": "a"}
        assert t.best("score", minimize=False) == {"s": "a"}

    def test_fleet_axis_keys(self):
        t = SweepSummary(
            columns=("fleet",) + self.COLS,
            rows=(("n4", "a", "s", 2.0), ("n4", "b", "s", 1.0),
                  ("n8", "a", "s", 1.0), ("n8", "b", "s", 1.0)),
        )
        assert t.best("score", minimize=True) == {"n4/s": "b", "n8/s": "a"}


class TestEmaSeeding:
    def test_first_step_not_double_counted(self):
        """Predictive at t=0 must see the seed EMA (= arrivals[0]), and the
        t=1 EMA must be one single update away from it."""
        cfg = SimConfig(ema_alpha=0.5)
        arr = jnp.stack([
            jnp.asarray([100.0, 0.0, 0.0, 0.0], jnp.float32),
            jnp.asarray([0.0, 100.0, 0.0, 0.0], jnp.float32),
        ])
        tr = simulate("predictive", arr, FLEET, cfg)
        g0 = np.asarray(tr.allocation[0])
        expect0 = np.asarray(
            alloc.predictive_adaptive(arr[0], FLEET.min_gpu, FLEET.priority, cfg.g_total)
        )
        np.testing.assert_allclose(g0, expect0, atol=1e-6)
        ema1 = alloc.ema_forecast(arr[0], arr[1], cfg.ema_alpha)
        expect1 = np.asarray(
            alloc.predictive_adaptive(ema1, FLEET.min_gpu, FLEET.priority, cfg.g_total)
        )
        np.testing.assert_allclose(np.asarray(tr.allocation[1]), expect1, atol=1e-6)
