"""Cross-validation: lax.scan simulator vs the independent numpy oracle."""
import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.core import allocator as alloc
from repro.core import workload
from repro.core.agents import paper_fleet, PAPER_ARRIVAL_RATES
from repro.core.reference_sim import SUPPORTED_POLICIES, simulate_numpy
from repro.core.simulator import simulate

FLEET = paper_fleet()
POLICIES = SUPPORTED_POLICIES


def test_oracle_covers_the_whole_registry():
    """Regression: the oracle used to hardcode 5 of the registry's 7
    entries and raise ValueError on the rest."""
    assert set(alloc.policy_names()) <= set(SUPPORTED_POLICIES)


def test_oracle_rejects_unknown_policy():
    arr = np.zeros((3, 4))
    try:
        simulate_numpy("nope", arr, FLEET)
    except ValueError as e:
        assert "nope" in str(e)
    else:
        raise AssertionError("expected ValueError")


@hypothesis.given(
    rates=st.lists(st.floats(0, 300), min_size=4, max_size=4),
    policy=st.sampled_from(POLICIES),
    steps=st.integers(5, 40),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_scan_matches_numpy_oracle(rates, policy, steps):
    arr = workload.constant(jnp.asarray(rates, jnp.float32), steps)
    tr = simulate(policy, arr, FLEET)
    ref = simulate_numpy(policy, np.asarray(arr), FLEET)
    for field in ("allocation", "served", "queue", "latency", "completed"):
        got = np.asarray(getattr(tr, field), np.float64)
        np.testing.assert_allclose(got, ref[field], rtol=2e-4, atol=2e-3,
                                   err_msg=f"{policy}/{field}")


def test_paper_workload_all_policies_match():
    arr = workload.constant(jnp.asarray(PAPER_ARRIVAL_RATES), 100)
    for policy in POLICIES:
        tr = simulate(policy, arr, FLEET)
        ref = simulate_numpy(policy, np.asarray(arr), FLEET)
        np.testing.assert_allclose(
            np.asarray(tr.queue, np.float64), ref["queue"], rtol=2e-4, atol=0.5,
            err_msg=policy,
        )
