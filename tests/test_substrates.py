"""Substrate tests: optimizer, schedule, data pipeline, checkpoint, sharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.distributed import sharding as shd
from repro.models.params import decl
from repro.training import optimizer as opt


class TestOptimizer:
    def test_adamw_converges_on_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init_opt_state(params)
        cfg = opt.OptimizerConfig(lr=0.3, warmup_steps=0, total_steps=200,
                                  weight_decay=0.0, grad_clip=100.0)
        for _ in range(150):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = opt.adamw_update(grads, state, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clip(self):
        params = {"w": jnp.zeros(3)}
        state = opt.init_opt_state(params)
        cfg = opt.OptimizerConfig(grad_clip=1.0, warmup_steps=0)
        big = {"w": jnp.full(3, 1e6)}
        _, _, stats = opt.adamw_update(big, state, params, cfg)
        assert float(stats["grad_norm"]) > 1e6  # reported pre-clip

    def test_schedule_shape(self):
        cfg = opt.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        s = [float(opt.schedule(jnp.asarray(i), cfg)) for i in (0, 5, 10, 55, 100, 200)]
        assert s[0] == 0.0 and abs(s[1] - 0.5) < 1e-6  # linear warmup
        assert abs(s[2] - 1.0) < 1e-6                  # peak
        assert s[3] < s[2] and s[4] < s[3]             # cosine decay
        assert abs(s[4] - 0.1) < 1e-2                  # floor
        assert abs(s[5] - 0.1) < 1e-2

    def test_state_dtype_f32(self):
        params = {"w": jnp.zeros(3, jnp.bfloat16)}
        state = opt.init_opt_state(params)
        assert state["m"]["w"].dtype == jnp.float32


class TestData:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        a = SyntheticTokens(cfg).batch(7)
        b = SyntheticTokens(cfg).batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_label_shift_and_mask(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        b = SyntheticTokens(cfg).batch(0)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
        assert (b["labels"][:, -1] == -1).all()

    def test_steps_differ(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        d = SyntheticTokens(cfg)
        assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])


class TestCheckpoint:
    def test_roundtrip_bf16(self, tmp_path):
        tree = {
            "a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3) * 0.1,
            "nested": {"b": jnp.ones((4,), jnp.float32), "step": jnp.int32(7)},
        }
        path = os.path.join(tmp_path, "ck.npz")
        ckpt.save(path, tree)
        got = ckpt.restore(path, tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(got)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_shape_mismatch_raises(self, tmp_path):
        path = os.path.join(tmp_path, "ck.npz")
        ckpt.save(path, {"a": jnp.zeros((2,))})
        with pytest.raises(ValueError):
            ckpt.restore(path, {"a": jnp.zeros((3,))})


class TestSharding:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_divisible_dims_shard(self):
        mesh = jax.make_mesh((1,), ("data",))
        spec = shd.spec_for_axes(mesh, (16, 32), ("embed", "ffn"), shd.TRAIN_RULES)
        assert spec == P("data")  # ffn -> model not in mesh -> replicated

    def test_non_divisible_falls_back(self):
        mesh = self._mesh()
        # 7 not divisible by model axis (1 divides everything, use fake dim)
        spec = shd.spec_for_axes(mesh, (7,), ("vocab",), shd.TRAIN_RULES)
        assert spec == P("model")  # axis size 1 divides 7

    def test_axis_used_once(self):
        mesh = self._mesh()
        spec = shd.spec_for_axes(
            mesh, (8, 8), ("ffn", "heads"), shd.TRAIN_RULES
        )
        # both want "model"; second falls back to replicated
        assert spec in (P("model"), P("model", None))

    def test_serve_rules_shard_cache_seq(self):
        mesh = self._mesh()
        spec = shd.spec_for_axes(
            mesh, (4, 128, 2, 16), ("batch", "kv_seq", "kv_heads", None),
            shd.SERVE_RULES,
        )
        assert spec[1] == "model"

    def test_full_model_decl_specs_build(self):
        from repro.configs import get_config
        from repro.models.model import build_model

        mesh = self._mesh()
        for arch in ("llama3-405b", "mixtral-8x7b", "mamba2-370m"):
            api = build_model(get_config(arch))
            tree = shd.shardings_for_decls(mesh, api.param_decls, shd.TRAIN_RULES)
            assert len(jax.tree_util.tree_leaves(tree)) > 0
