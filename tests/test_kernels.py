"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes, dtypes, GQA group sizes and mask modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import ref as aref
from repro.kernels.attention.decode_attention import decode_attention
from repro.kernels.attention.flash_attention import flash_attention
from repro.kernels.ssd import ref as sref
from repro.kernels.ssd.ssd_scan import ssd


def _rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


FLASH_CASES = [
    # (b, s_q, s_kv, h, kv, d, causal, window)
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 200, 200, 8, 8, 128, True, 0),     # MHA, non-divisible seq (padding)
    (2, 64, 256, 4, 1, 32, False, 0),      # cross/bidirectional, MQA
    (1, 256, 256, 4, 2, 64, True, 64),     # sliding window
    (2, 96, 96, 6, 3, 64, True, 0),
    (1, 128, 512, 4, 4, 128, True, 0),     # q shorter than kv (continuation)
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, s_q, s_kv, h, kv, d, causal, window = case
    keys = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = _rand(keys[0], b, s_q, h, d, dtype=dtype)
    k = _rand(keys[1], b, s_kv, kv, d, dtype=dtype)
    v = _rand(keys[2], b, s_kv, kv, d, dtype=dtype)
    off = s_kv - s_q if causal else 0
    want = aref.mha(q, k, v, causal=causal, window=window, q_offset=off)
    got = flash_attention(q, k, v, causal=causal, window=window, q_offset=off,
                          block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=_tol(dtype)
    )


DECODE_CASES = [
    # (b, h, kv, d, s_max, cache_len, window)
    (2, 8, 2, 64, 300, 150, 0),
    (1, 4, 4, 128, 512, 512, 0),
    (3, 16, 2, 64, 256, 256, 128),   # rolling sliding-window cache
    (2, 4, 1, 32, 1024, 700, 0),     # MQA, partially filled
    (1, 8, 8, 64, 96, 1, 0),         # single valid entry
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    b, h, kv, d, s_max, clen, window = case
    keys = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = _rand(keys[0], b, h, d, dtype=dtype)
    kc = _rand(keys[1], b, s_max, kv, d, dtype=dtype)
    vc = _rand(keys[2], b, s_max, kv, d, dtype=dtype)
    want = aref.decode_gqa(q, kc, vc, jnp.int32(clen), window=window)
    got = decode_attention(q, kc, vc, jnp.int32(clen), window=window,
                           block_k=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=_tol(dtype)
    )


def test_decode_attention_per_example_lengths():
    b, h, kv, d, s_max = 3, 4, 2, 32, 128
    keys = jax.random.split(jax.random.key(7), 3)
    q = _rand(keys[0], b, h, d)
    kc = _rand(keys[1], b, s_max, kv, d)
    vc = _rand(keys[2], b, s_max, kv, d)
    lens = jnp.asarray([5, 77, 128], jnp.int32)
    want = aref.decode_gqa(q, kc, vc, lens)
    got = decode_attention(q, kc, vc, lens, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


SSD_CASES = [
    # (b, s, h, p, n, chunk)
    (2, 128, 4, 32, 16, 32),
    (1, 96, 2, 64, 32, 32),
    (2, 64, 8, 16, 8, 16),
    (1, 100, 2, 32, 16, 32),  # non-divisible seq (padding path)
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_matches_naive(case, dtype):
    b, s, h, p, n, chunk = case
    keys = jax.random.split(jax.random.key(hash(case) % 2**31), 5)
    x = (_rand(keys[0], b, s, h, p) * 0.5).astype(dtype)
    dt = jax.nn.softplus(_rand(keys[1], b, s, h))
    A = -jnp.exp(_rand(keys[2], h) * 0.3)
    Bm = _rand(keys[3], b, s, n).astype(dtype)
    Cm = _rand(keys[4], b, s, n).astype(dtype)
    D = jnp.ones((h,))
    want_y, want_h = sref.ssd_naive(x, dt, A, Bm, Cm, D)
    got_y, got_h = ssd(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got_y, np.float32), np.asarray(want_y, np.float32), atol=tol
    )
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h), atol=tol)


def test_ssd_chunked_ref_matches_naive():
    b, s, h, p, n = 2, 128, 4, 32, 16
    keys = jax.random.split(jax.random.key(3), 5)
    x = _rand(keys[0], b, s, h, p) * 0.5
    dt = jax.nn.softplus(_rand(keys[1], b, s, h))
    A = -jnp.exp(_rand(keys[2], h) * 0.3)
    Bm, Cm = _rand(keys[3], b, s, n), _rand(keys[4], b, s, n)
    D = jnp.ones((h,))
    y0, h0 = sref.ssd_naive(x, dt, A, Bm, Cm, D)
    y1, h1 = sref.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-4)


def test_ssd_initial_state():
    """Decode restart: SSD with h0 == continuing the naive recurrence."""
    b, s, h, p, n = 1, 64, 2, 16, 8
    keys = jax.random.split(jax.random.key(9), 6)
    x = _rand(keys[0], b, s, h, p) * 0.5
    dt = jax.nn.softplus(_rand(keys[1], b, s, h))
    A = -jnp.exp(_rand(keys[2], h) * 0.3)
    Bm, Cm = _rand(keys[3], b, s, n), _rand(keys[4], b, s, n)
    D = jnp.ones((h,))
    h0 = _rand(keys[5], b, h, p, n)
    want_y, want_h = sref.ssd_naive(x, dt, A, Bm, Cm, D, h0=h0)
    got_y, got_h = ssd(x, dt, A, Bm, Cm, D, h0=h0, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y), atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h), atol=1e-4)


def test_ssd_decode_step_consistency():
    """Step-by-step decode equals the full scan."""
    b, s, h, p, n = 1, 8, 2, 16, 8
    keys = jax.random.split(jax.random.key(11), 5)
    x = _rand(keys[0], b, s, h, p) * 0.5
    dt = jax.nn.softplus(_rand(keys[1], b, s, h))
    A = -jnp.exp(_rand(keys[2], h) * 0.3)
    Bm, Cm = _rand(keys[3], b, s, n), _rand(keys[4], b, s, n)
    D = jnp.ones((h,))
    want_y, want_h = sref.ssd_naive(x, dt, A, Bm, Cm, D)
    hstate = jnp.zeros((b, h, p, n))
    for t in range(s):
        y_t, hstate = sref.ssd_decode_step(
            x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, hstate
        )
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(want_y[:, -1]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hstate), np.asarray(want_h), atol=1e-5)


@pytest.mark.parametrize("case", [(2, 256, 4, 2, 32, 64), (1, 128, 8, 8, 64, 32),
                                  (2, 512, 6, 3, 32, 128), (1, 64, 4, 1, 16, 32)])
def test_banded_swa_matches_masked_full(case):
    """Banded sliding-window prefill == full attention with window mask."""
    b, s, h, kv, d, w = case
    keys = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = _rand(keys[0], b, s, h, d)
    k = _rand(keys[1], b, s, kv, d)
    v = _rand(keys[2], b, s, kv, d)
    want = aref.mha(q, k, v, causal=True, window=w)
    got = aref.mha_banded(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
