"""Shared test configuration.

``hypothesis`` is an optional dev dependency (see pyproject.toml).  When it
is installed we pin a deterministic profile so property tests are
reproducible in CI; when it is missing we install a minimal stub into
``sys.modules`` *before* the test modules import it, so

* all example-based tests still collect and run, and
* every ``@hypothesis.given`` test skips cleanly instead of erroring.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import pytest

try:
    import hypothesis
except ImportError:
    def _given(*args, **kwargs):
        given_names = set(kwargs)
        num_positional = len(args)

        def deco(fn):
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items() if name not in given_names]
            # Positional strategies are matched against the rightmost
            # parameters; hide that many as well.
            if num_positional:
                keep = keep[:-num_positional]

            @functools.wraps(fn)
            def skipper(*_a, **_k):
                pytest.skip("hypothesis not installed; property test skipped")

            # Hide the strategy-driven parameters so pytest does not look
            # for fixtures with those names.
            skipper.__signature__ = sig.replace(parameters=keep)
            return skipper

        return deco

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    def _strategy(*_args, **_kwargs):
        return None

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _stub.assume = lambda *_a, **_k: True
    _stub.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    _st = types.ModuleType("hypothesis.strategies")
    for _name in (
        "lists", "floats", "integers", "booleans", "sampled_from",
        "tuples", "one_of", "just", "text", "composite",
    ):
        setattr(_st, _name, _strategy)
    _stub.strategies = _st
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _st
else:
    hypothesis.settings.register_profile(
        "repro", derandomize=True, deadline=None, print_blob=True
    )
    hypothesis.settings.load_profile("repro")
