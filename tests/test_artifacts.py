"""Dry-run artifact contract tests + HLO collective-parser unit tests.

The artifact tests validate the recorded experiments/ tree (skipped when
absent, e.g. on a fresh clone before running the launch scripts).
"""
import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.dryrun import collective_bytes
from repro.models.model import INPUT_SHAPES, shape_applicable

HAVE = os.path.isdir("experiments/dryrun")

LONG_OK = {"mamba2-370m", "recurrentgemma-9b", "mixtral-8x7b"}


class TestCollectiveParser:
    HLO = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[8,8]{1,0} all-reduce(%x), to_apply=%add
  %ars = f32[4,4]{1,0} all-reduce-start(%y), to_apply=%add
  %ard = f32[4,4]{1,0} all-reduce-done(%ars)
  %rs = (f32[2,2]{1,0}, f32[2,2]{1,0}) reduce-scatter(%a, %b), dimensions={0}
  %a2a.5 = f32[16]{0} all-to-all(%c), dimensions={0}
  %cp = u32[128]{0} collective-permute(%d), source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(%l, %r), lhs_contracting_dims={1}
"""

    def test_counts_each_kind_once(self):
        out = collective_bytes(self.HLO)
        assert out["all-gather"] == 16 * 1024 * 2
        # all-reduce: plain + -start counted, -done not double-counted
        assert out["all-reduce"] == 8 * 8 * 4 + 4 * 4 * 4
        assert out["reduce-scatter"] == 2 * (2 * 2 * 4)  # tuple summed
        assert out["all-to-all"] == 16 * 4
        assert out["collective-permute"] == 128 * 4
        assert out["total"] == sum(
            out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute")
        )

    def test_ignores_non_collectives(self):
        assert collective_bytes("%dot = f32[8,8]{1,0} dot(%a, %b)")["total"] == 0


@pytest.mark.skipif(not HAVE, reason="no dry-run artifacts recorded")
class TestDryRunArtifacts:
    @pytest.mark.parametrize("mesh", ["pod1", "pod2"])
    def test_every_pair_recorded_and_clean(self, mesh):
        ok, skipped, errors = 0, 0, []
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                path = f"experiments/dryrun/{arch}_{shape}_{mesh}.json"
                assert os.path.exists(path), path
                d = json.load(open(path))
                if "error" in d:
                    errors.append(path)
                elif "skipped" in d:
                    skipped += 1
                else:
                    ok += 1
        assert not errors, errors
        assert ok == 33 and skipped == 7

    def test_skips_match_applicability_matrix(self):
        for arch in ARCH_IDS:
            d = json.load(open(f"experiments/dryrun/{arch}_long_500k_pod1.json"))
            expect_ok = arch in LONG_OK
            assert ("skipped" not in d) == expect_ok, arch

    def test_memory_fits_hbm(self):
        """Per-device argument bytes must fit a 16 GB chip for every pair."""
        from repro.launch.mesh import HW

        for f in glob.glob("experiments/dryrun/*_pod1.json"):
            d = json.load(open(f))
            pd = d.get("per_device")
            if not pd:
                continue
            arg = pd.get("argument_bytes")
            if arg is not None:
                assert arg < HW["hbm_bytes"], (f, arg)

    def test_multipod_halves_or_matches_per_device_flops(self):
        """512 chips never do MORE per-device work than 256 (sanity)."""
        for arch in ("llama3-405b", "mixtral-8x7b", "mamba2-370m"):
            a = json.load(open(f"experiments/dryrun/{arch}_train_4k_pod1.json"))
            b = json.load(open(f"experiments/dryrun/{arch}_train_4k_pod2.json"))
            if "per_device" in a and "per_device" in b:
                assert b["per_device"]["flops"] <= a["per_device"]["flops"] * 1.05
