"""Fleet-simulator tests: Table II reproduction + §V-B robustness."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import workload
from repro.core.agents import Fleet, AgentSpec, PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.simulator import SimConfig, run_policy, simulate, summarize

FLEET = paper_fleet()
ARR = workload.constant(jnp.asarray(PAPER_ARRIVAL_RATES), 100)


class TestTable2:
    """The paper's headline numbers (Table II + §V-A prose)."""

    def test_static_equal(self):
        s = run_policy("static_equal", ARR, FLEET)
        assert abs(s.avg_latency - 110.3) < 1.0
        assert abs(s.total_throughput - 60.0) < 0.05
        assert abs(s.cost - 0.020) < 1e-6

    def test_round_robin(self):
        s = run_policy("round_robin", ARR, FLEET)
        assert abs(s.avg_latency - 756.1) < 5.0
        assert abs(s.total_throughput - 60.0) < 0.5
        assert abs(s.cost - 0.020) < 1e-6
        assert s.latency_std < 1.0          # paper: 0.5 — starvation clipping

    def test_adaptive(self):
        s = run_policy("adaptive", ARR, FLEET)
        assert abs(s.avg_latency - 111.9) < 1.0
        assert abs(s.total_throughput - 58.1) < 0.1
        assert abs(s.cost - 0.020) < 1e-6
        # §V-A per-agent: reasoning lowest (91.6), vision highest (128.6).
        lat = dict(zip(FLEET.names, s.per_agent_latency))
        assert abs(lat["specialist_reasoning"] - 91.6) < 1.0
        assert abs(lat["specialist_vision"] - 128.6) < 1.0
        assert min(lat, key=lat.get) == "specialist_reasoning"

    def test_85pct_latency_reduction(self):
        a = run_policy("adaptive", ARR, FLEET)
        r = run_policy("round_robin", ARR, FLEET)
        assert 1 - a.avg_latency / r.avg_latency > 0.84

    def test_equal_cost_across_policies(self):
        costs = {run_policy(p, ARR, FLEET).cost for p in
                 ("static_equal", "round_robin", "adaptive")}
        assert len(costs) == 1

    def test_coordinator_throughput_prose(self):
        """§V-A: coordinator ~20 rps under adaptive despite minimal share."""
        s = run_policy("adaptive", ARR, FLEET)
        tput = dict(zip(FLEET.names, s.per_agent_throughput))
        assert 18.0 < tput["coordinator"] < 26.0


class TestRobustness:
    """§V-B: overload, spikes, domination."""

    def test_3x_overload_graceful(self):
        arr = workload.scaled(jnp.asarray(PAPER_ARRIVAL_RATES), 100, 3.0)
        s = run_policy("adaptive", arr, FLEET)
        # No starvation: every agent keeps serving.
        assert min(s.per_agent_throughput) > 1.0
        assert s.total_throughput > 55.0

    def test_spike_adaptation_within_one_step(self):
        arr = workload.spike(jnp.asarray(PAPER_ARRIVAL_RATES), 100,
                             spike_agent=3, spike_start=50, spike_len=10)
        tr = simulate("adaptive", arr, FLEET)
        g = np.asarray(tr.allocation)
        # allocation for agent 3 jumps at the spike step (next-step latency <= 1 tick)
        assert g[50, 3] > g[49, 3] + 0.02

    def test_domination_no_monopoly(self):
        arr = workload.dominated(jnp.asarray(PAPER_ARRIVAL_RATES), 100, agent=0, share=0.9)
        tr = simulate("adaptive", arr, FLEET)
        g = np.asarray(tr.allocation).mean(0)
        # 90% of requests but priority weighting keeps the rest alive
        assert g[0] < 0.6
        assert (g[1:] > 0.05).all()


class TestInvariants:
    @hypothesis.given(
        rates=st.lists(st.floats(0, 500), min_size=4, max_size=4),
        policy=st.sampled_from(["static_equal", "round_robin", "adaptive",
                                "water_filling", "predictive", "throughput_greedy"]),
    )
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_conservation_and_capacity(self, rates, policy):
        arr = workload.constant(jnp.asarray(rates, jnp.float32), 30)
        tr = simulate(policy, arr, FLEET)
        g = np.asarray(tr.allocation)
        q = np.asarray(tr.queue)
        served = np.asarray(tr.served)
        assert (g.sum(1) <= 1 + 1e-4).all()
        assert (q >= -1e-3).all()
        assert (served >= -1e-6).all()
        # served never exceeds capacity
        cap = g * np.asarray(FLEET.base_throughput)[None]
        assert (served <= cap + 1e-3).all()
        # flow conservation: total arrived == served + final queue
        arrived = np.asarray(tr.arrivals).sum(0)
        np.testing.assert_allclose(arrived, served.sum(0) + q[-1], rtol=1e-4, atol=1e-2)

    def test_poisson_workload_runs(self):
        arr = workload.poisson(jnp.asarray(PAPER_ARRIVAL_RATES), 50, jax.random.key(0))
        s = run_policy("adaptive", arr, FLEET)
        assert np.isfinite(s.avg_latency)

    def test_latency_cap_respected(self):
        tr = simulate("round_robin", ARR, FLEET)
        assert float(np.asarray(tr.latency).max()) <= SimConfig().latency_cap

    def test_dominated_single_agent_raises(self):
        """Regression: n=1 used to divide by zero (n-1) and emit nan rates
        instead of failing loudly."""
        with pytest.raises(ValueError, match=">= 2 agents"):
            workload.dominated(jnp.asarray([80.0]), 10, agent=0)

    def test_dominated_two_agents_still_works(self):
        arr = np.asarray(workload.dominated(jnp.asarray([80.0, 40.0]), 5,
                                            agent=0, share=0.9))
        assert np.isfinite(arr).all()
        np.testing.assert_allclose(arr[0].sum(), 120.0, rtol=1e-5)
        np.testing.assert_allclose(arr[0, 0], 108.0, rtol=1e-5)
