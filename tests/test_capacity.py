"""Serverless capacity layer: the no-op guarantee, autoscaler dynamics,
oracle parity, billing, and the vmapped capacity axis of the sweep grid."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import allocator as alloc
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.core.capacity import (
    COLD_START_HORIZON,
    billing_cost,
    capacity_config,
    capacity_policy_id,
    capacity_policy_names,
    check_capacity,
    stack_capacities,
)
from repro.core.reference_sim import simulate_numpy
from repro.core.routing import pipeline_chain
from repro.core.simulator import (
    METRIC_NAMES,
    SimConfig,
    run_policy,
    simulate,
    summarize,
)
from repro.core.sweep import (
    Scenario,
    capacity_scenario_library,
    scenario_library,
    sweep_capacity,
)

FLEET = paper_fleet()
RATES = jnp.asarray(PAPER_ARRIVAL_RATES, jnp.float32)
TRACE_FIELDS = ("allocation", "served", "queue", "latency", "arrivals",
                "completed", "warm", "pending")
ELASTIC = SimConfig(g_total=1.0, num_gpus=8.0)


def _onoff_arrivals(num_steps=60, on_until=10, scale=0.2):
    """Traffic for the first ``on_until`` steps, then silence — the
    scale-to-zero litmus workload."""
    arr = np.zeros((num_steps, 4), np.float32)
    arr[:on_until] = np.asarray(PAPER_ARRIVAL_RATES, np.float32) * scale
    return jnp.asarray(arr)


class TestRegistry:
    def test_three_capacity_policies_registered(self):
        assert set(capacity_policy_names()) >= {"fixed", "reactive",
                                                "scale_to_zero"}

    def test_ids_are_registration_order(self):
        for i, name in enumerate(capacity_policy_names()):
            assert capacity_policy_id(name) == i

    def test_unknown_capacity_policy_raises(self):
        with pytest.raises(ValueError, match="registered"):
            capacity_config("warm_and_fuzzy")

    def test_config_policy_roundtrip(self):
        for name in capacity_policy_names():
            assert capacity_config(name).policy == name


class TestBilling:
    def test_billing_formula(self):
        # 3600 instance-seconds at $0.72/h is $0.72
        assert abs(billing_cost(3600.0, 0.72) - 0.72) < 1e-9

    def test_simulator_cost_is_the_billing_helper(self):
        """DRY regression: the simulator's cost column must be the shared
        helper applied to the trace's warm-instance-seconds — no second
        formula anywhere."""
        tr = simulate("adaptive", workload.constant(RATES, 50), FLEET)
        s = summarize("adaptive", tr, SimConfig(), FLEET.active)
        expect = billing_cost(float(np.asarray(tr.warm).sum()),
                              SimConfig().price_per_hour)
        assert abs(s.cost - expect) < 1e-9

    def test_default_run_reproduces_table2_cost(self):
        s = run_policy("adaptive", workload.constant(RATES, 100), FLEET)
        assert abs(s.cost - 0.020) < 1e-6

    def test_step_objective_cost_term_scales_with_warm_pool(self):
        from repro.core.objective import ObjectiveWeights, step_objective

        g = jnp.full(4, 0.25)
        q = jnp.zeros(4)
        lam = RATES
        price = 0.0002
        one = step_objective(g, q, lam, FLEET.base_throughput,
                             ObjectiveWeights(), price, warm_instances=1.0)
        four = step_objective(g, q, lam, FLEET.base_throughput,
                              ObjectiveWeights(), price, warm_instances=4.0)
        # identical latency/throughput terms; only billing moved (f32
        # objective values are ~1e2, so the delta carries ~1e-6 noise)
        assert abs(float(four - one) - 3.0 * price) < 1e-5


class TestNoOpGuarantee:
    """The hard invariant: ``fixed`` capacity with zero cold start must
    reproduce the pre-capacity (static python-float budget) trajectories
    bit-for-bit for every registered allocation policy."""

    @pytest.mark.parametrize("policy", alloc.policy_names())
    def test_fixed_capacity_is_bit_for_bit_noop(self, policy):
        arr = workload.constant(RATES, 60)
        base = simulate(policy, arr, FLEET)
        capped = simulate(policy, arr, FLEET,
                          capacity=capacity_config("fixed"))
        for field in TRACE_FIELDS:
            a = np.asarray(getattr(base, field))
            b = np.asarray(getattr(capped, field))
            assert np.array_equal(a, b), (policy, field)

    def test_noop_holds_under_bursty_arrivals_and_workflow(self):
        import jax

        arr = workload.bursty(RATES, 50, jax.random.key(2))
        wf = pipeline_chain(FLEET.num_agents)
        for policy in ("adaptive", "throughput_greedy"):
            base = simulate(policy, arr, FLEET, workflow=wf)
            capped = simulate(policy, arr, FLEET, workflow=wf,
                              capacity=capacity_config("fixed"))
            for field in TRACE_FIELDS:
                assert np.array_equal(
                    np.asarray(getattr(base, field)),
                    np.asarray(getattr(capped, field)),
                ), (policy, field)

    def test_fixed_warm_trace_is_constant_budget(self):
        cfg = SimConfig(g_total=0.5, num_gpus=2.0)
        tr = simulate("adaptive", workload.constant(RATES, 30), FLEET, cfg,
                      capacity=capacity_config("fixed"))
        np.testing.assert_array_equal(np.asarray(tr.warm), 0.5)
        np.testing.assert_array_equal(np.asarray(tr.pending), 0.0)


class TestAutoscalerDynamics:
    def test_reactive_scales_up_under_load_and_respects_ceiling(self):
        cap = capacity_config("reactive", min_instances=1.0)
        tr = simulate("adaptive", workload.constant(RATES, 60), FLEET,
                      ELASTIC, capacity=cap)
        warm = np.asarray(tr.warm)
        assert warm.max() > 1.0            # elastic: grew past the baseline
        assert warm.max() <= ELASTIC.num_gpus + 1e-6
        assert warm.min() >= 1.0 - 1e-6    # floor honored
        # discrete instances: every step's pool is a whole count
        np.testing.assert_array_equal(warm, np.round(warm))

    def test_cold_start_delays_warmup(self):
        """With a k-second cold start, the pool cannot grow before step k:
        requests issued at t=0 warm up at t=k, and the pending gauge is
        positive in between."""
        k = 4
        cap = capacity_config("reactive", cold_start_s=float(k),
                              min_instances=1.0)
        tr = simulate("adaptive", workload.constant(RATES, 30), FLEET,
                      ELASTIC, capacity=cap)
        warm = np.asarray(tr.warm)
        pending = np.asarray(tr.pending)
        assert (warm[:k] == 1.0).all(), warm[:k]
        assert warm[k] > 1.0
        assert (pending[: k] > 0).any()
        # zero cold start grows immediately on the same workload
        tr0 = simulate("adaptive", workload.constant(RATES, 30), FLEET,
                       ELASTIC, capacity=capacity_config(
                           "reactive", min_instances=1.0))
        assert np.asarray(tr0.warm)[0] > 1.0

    def test_cold_start_stall_metric_counts_backlogged_cold_seconds(self):
        k = 4
        cap = capacity_config("reactive", cold_start_s=float(k),
                              min_instances=1.0)
        s = run_policy("adaptive", workload.constant(RATES, 30), FLEET,
                       ELASTIC, capacity=cap)
        assert s.cold_start_stall_time >= 1.0
        s0 = run_policy("adaptive", workload.constant(RATES, 30), FLEET,
                        ELASTIC,
                        capacity=capacity_config("reactive", min_instances=1.0))
        assert s0.cold_start_stall_time == 0.0

    def test_scale_to_zero_releases_pool_after_keep_alive(self):
        cap = capacity_config("scale_to_zero", keep_alive_s=5.0)
        tr = simulate("adaptive", _onoff_arrivals(), FLEET, ELASTIC,
                      capacity=cap)
        warm = np.asarray(tr.warm)
        assert warm[0] >= 1.0
        assert warm[-1] == 0.0             # pool fully released
        assert np.asarray(tr.allocation)[-1].sum() == 0.0
        # billing stopped with the pool: cheaper than the always-on run
        s = summarize("adaptive", tr, ELASTIC, FLEET.active)
        fixed = run_policy("adaptive", _onoff_arrivals(), FLEET, ELASTIC,
                           capacity=capacity_config("fixed"))
        assert s.cost < fixed.cost

    def test_scale_to_zero_honors_min_instances_while_busy(self):
        """The configured reactive floor still binds on the busy path;
        scale-to-zero only overrides it after the keep-alive window."""
        cap = capacity_config("scale_to_zero", keep_alive_s=5.0,
                              min_instances=3.0)
        tr = simulate("adaptive", _onoff_arrivals(on_until=10, scale=0.02),
                      FLEET, ELASTIC, capacity=cap)
        warm = np.asarray(tr.warm)
        assert (warm[1:8] >= 3.0).all(), warm[:8]   # floor binds under load
        assert warm[-1] == 0.0                      # ...but not when idle
        ref = simulate_numpy("adaptive",
                             np.asarray(_onoff_arrivals(on_until=10, scale=0.02)),
                             FLEET, capacity=cap, num_gpus=ELASTIC.num_gpus)
        np.testing.assert_allclose(warm.astype(np.float64), ref["warm"],
                                   atol=1e-5)

    def test_stacked_config_policy_accessor_raises_clearly(self):
        stacked = stack_capacities(capacity_scenario_library())
        with pytest.raises(ValueError, match="stacked batch"):
            stacked.policy

    def test_scale_to_zero_rewarms_on_new_traffic(self):
        arr = np.zeros((40, 4), np.float32)
        arr[:5] = np.asarray(PAPER_ARRIVAL_RATES, np.float32) * 0.1
        arr[30:] = np.asarray(PAPER_ARRIVAL_RATES, np.float32) * 0.1
        cap = capacity_config("scale_to_zero", keep_alive_s=3.0,
                              cold_start_s=2.0)
        tr = simulate("adaptive", jnp.asarray(arr), FLEET, ELASTIC,
                      capacity=cap)
        warm = np.asarray(tr.warm)
        assert (warm[15:30] == 0.0).any()  # slept through the gap
        assert warm[-1] >= 1.0             # woke up for the second wave

    def test_budget_feasible_under_time_varying_capacity(self):
        """Σg(t) <= warm(t) and g >= 0 for every policy when the budget is
        a traced trajectory, not a constant."""
        import jax

        arr = workload.bursty(RATES, 50, jax.random.key(7))
        cap = capacity_config("reactive", cold_start_s=2.0, min_instances=0.0)
        for policy in alloc.policy_names():
            tr = simulate(policy, arr, FLEET, ELASTIC, capacity=cap)
            g = np.asarray(tr.allocation)
            warm = np.asarray(tr.warm)
            assert (g >= -1e-6).all(), policy
            assert (g.sum(axis=-1) <= warm * (1 + 1e-4) + 1e-6).all(), policy


class TestRevocationInteractions:
    """Capacity-layer edge cases under the failure injectors (PR 10)."""

    def test_revocation_during_pending_cold_start(self):
        """Instances revoked while replacements are still in the cold-start
        pipeline: the pipeline must survive the revocation (pending mass is
        not warm yet, so phi cannot touch it) and keep delivering — the
        pool recovers instead of collapsing."""
        from repro.core.failures import failure_spec

        k = 4
        cap = capacity_config("reactive", cold_start_s=float(k),
                              min_instances=1.0)
        spec = failure_spec("revoker", revoke_p_enter=0.3, revoke_p_exit=0.3,
                            revoke_frac=0.8, seed=5)
        tr = simulate("adaptive", workload.constant(RATES, 60), FLEET,
                      ELASTIC, capacity=cap, failures=spec)
        warm = np.asarray(tr.warm)
        pending = np.asarray(tr.pending)
        assert (warm >= -1e-6).all()
        assert (warm <= ELASTIC.num_gpus + 1e-6).all()
        assert (pending >= -1e-6).all()
        # replacements were provisioned after the first revocation hit
        first_hit = int(np.argmax(warm < warm[0]))
        assert pending[first_hit:].max() > 0
        # and the pool actually recovered above its post-revocation trough
        assert warm[first_hit:].max() > warm[first_hit] + 0.5

    def test_keep_alive_racing_revocation(self):
        """scale_to_zero holds idle instances for keep_alive_s — while a
        permanent 50% revocation strips half of them.  The race resolves
        as: (1) the revoked half is never billed during the keep-alive
        window, (2) the keep-alive clock stays demand-driven — revocation
        slows the drain (serving scales by 1-phi) and can only *delay*
        the release, never trigger it early — and (3) the pool still
        reaches zero once the idle window expires."""
        from repro.core.failures import failure_spec

        # Light traffic so the backlog clears well inside the horizon
        # even at half capacity.
        cap = capacity_config("scale_to_zero", keep_alive_s=8.0)
        arr = _onoff_arrivals(num_steps=60, on_until=10, scale=0.05)
        base = simulate("static_equal", arr, FLEET, ELASTIC, capacity=cap)
        spec = failure_spec("perma_revoke", revoke_p_enter=1.0,
                            revoke_p_exit=0.0, revoke_frac=0.5, seed=0)
        rev = simulate("static_equal", arr, FLEET, ELASTIC, capacity=cap,
                       failures=spec)
        warm_base = np.asarray(base.warm)
        warm_rev = np.asarray(rev.warm)
        assert warm_rev[-1] == 0.0                  # still releases
        # billed warm never exceeds the surviving half while the pool is up
        assert warm_rev.max() <= 0.5 * warm_base.max() + 1e-6
        rel_base = int(np.argmax(warm_base == 0.0))
        rel_rev = int(np.argmax(warm_rev == 0.0))
        assert rel_rev >= rel_base, (rel_rev, rel_base)
        # half the pool revoked for the whole window: cheaper despite the
        # longer drain
        s_base = summarize("static_equal", base, ELASTIC, FLEET.active)
        s_rev = summarize("static_equal", rev, ELASTIC, FLEET.active)
        assert s_rev.cost < s_base.cost

    def test_billing_excludes_revoked_instance_seconds(self):
        """A permanent 50% revocation halves the billed warm-instance-
        seconds exactly: revoked capacity is never billed, on both the
        fixed pool and the capacity-layer path."""
        from repro.core.failures import failure_spec

        arr = workload.constant(RATES, 60)
        spec = failure_spec("half_gone", revoke_p_enter=1.0,
                            revoke_p_exit=0.0, revoke_frac=0.5, seed=0)
        for cap in (None, capacity_config("fixed")):
            base = run_policy("static_equal", arr, FLEET, capacity=cap)
            rev = run_policy("static_equal", arr, FLEET, capacity=cap,
                             failures=spec)
            assert rev.cost == pytest.approx(0.5 * base.cost, rel=1e-6), cap
        # the warm trace itself records the billed (post-revocation) pool
        tr = simulate("static_equal", arr, FLEET, failures=spec)
        np.testing.assert_allclose(np.asarray(tr.warm), 0.5)


class TestOracleParity:
    """The numpy oracle must track the JAX scan under elastic capacity."""

    @pytest.mark.parametrize("policy", alloc.policy_names())
    def test_reactive_with_cold_start(self, policy):
        arr = workload.constant(RATES, 50)
        cap = capacity_config("reactive", cold_start_s=3.0, min_instances=1.0)
        tr = simulate(policy, arr, FLEET, ELASTIC, capacity=cap)
        ref = simulate_numpy(policy, np.asarray(arr), FLEET, capacity=cap,
                             num_gpus=ELASTIC.num_gpus)
        for field in ("allocation", "served", "queue", "latency", "warm",
                      "pending"):
            np.testing.assert_allclose(
                np.asarray(getattr(tr, field), np.float64), ref[field],
                rtol=2e-4, atol=2e-3, err_msg=f"{policy}/{field}",
            )

    @pytest.mark.parametrize("policy", ("adaptive", "water_filling",
                                        "throughput_greedy"))
    def test_scale_to_zero(self, policy):
        arr = _onoff_arrivals()
        cap = capacity_config("scale_to_zero", keep_alive_s=4.0,
                              cold_start_s=2.0)
        tr = simulate(policy, arr, FLEET, ELASTIC, capacity=cap)
        ref = simulate_numpy(policy, np.asarray(arr), FLEET, capacity=cap,
                             num_gpus=ELASTIC.num_gpus)
        for field in ("allocation", "served", "queue", "latency", "warm",
                      "pending"):
            np.testing.assert_allclose(
                np.asarray(getattr(tr, field), np.float64), ref[field],
                rtol=2e-4, atol=2e-3, err_msg=f"{policy}/{field}",
            )


class TestValidation:
    def test_budget_above_ceiling_rejected(self):
        with pytest.raises(ValueError, match="ceiling"):
            SimConfig(g_total=4.0, num_gpus=2.0)

    def test_cold_start_beyond_horizon_rejected(self):
        cap = capacity_config("reactive",
                              cold_start_s=float(COLD_START_HORIZON))
        with pytest.raises(ValueError, match="cold_start"):
            check_capacity(cap, 1.0, 8.0)

    def test_min_instances_above_ceiling_rejected(self):
        cap = capacity_config("reactive", min_instances=9.0)
        with pytest.raises(ValueError, match="min_instances"):
            check_capacity(cap, 1.0, 8.0)

    def test_simulate_checks_capacity_eagerly(self):
        cap = capacity_config("reactive",
                              cold_start_s=float(COLD_START_HORIZON + 5))
        with pytest.raises(ValueError, match="cold_start"):
            simulate("adaptive", workload.constant(RATES, 5), FLEET,
                     ELASTIC, capacity=cap)


class TestSweepCapacityGrid:
    @pytest.fixture(scope="class")
    def grid(self):
        scenarios = scenario_library(PAPER_ARRIVAL_RATES, num_steps=40, seed=0)
        return scenarios, sweep_capacity(
            FLEET, scenarios=scenarios, config=ELASTIC
        )

    def test_grid_shape_and_axis_names(self, grid):
        scenarios, res = grid
        c = len(capacity_scenario_library())
        p, w = len(alloc.policy_names()), len(scenarios)
        assert res.metrics.shape == (c, p, w, len(METRIC_NAMES))
        assert res.capacity_names == tuple(
            cc.name for cc in capacity_scenario_library()
        )
        assert np.isfinite(res.metrics).all()

    def test_cells_match_run_policy(self, grid):
        scenarios, res = grid
        caps = {c.name: c for c in capacity_scenario_library()}
        for cap_name in ("fixed", "reactive_cold"):
            got = res.summary("adaptive", "constant", capacity=cap_name)
            want = run_policy("adaptive", scenarios[0].arrivals, FLEET,
                              ELASTIC, capacity=caps[cap_name])
            assert abs(got.avg_latency - want.avg_latency) < 1e-3, cap_name
            assert abs(got.cost - want.cost) < 1e-6, cap_name
            assert abs(got.mean_warm_instances
                       - want.mean_warm_instances) < 1e-4, cap_name

    def test_cost_constant_under_fixed_but_not_under_elastic(self, grid):
        _, res = grid
        cost = res.metric("cost")  # (C, P, W)
        fixed = res.capacity_names.index("fixed")
        assert np.ptp(cost[fixed]) < 1e-9
        for scen in ("diurnal", "bursty"):
            w = res.scenario_names.index(scen)
            for cap_name in ("reactive", "reactive_cold", "scale_to_zero"):
                c = res.capacity_names.index(cap_name)
                spread = cost[c, :, w].max() - cost[c, :, w].min()
                assert spread > 0.0, (cap_name, scen)

    def test_table_and_best_carry_capacity_axis(self, grid):
        _, res = grid
        table = res.table()
        assert table.columns[0] == "capacity"
        assert "cost" in table.columns
        best = table.best("cost")
        assert set(best) == {
            f"{c}/{s}" for c in res.capacity_names for s in res.scenario_names
        }

    def test_duplicate_capacity_names_rejected(self):
        caps = (capacity_config("fixed"), capacity_config("fixed"))
        with pytest.raises(ValueError, match="unique"):
            sweep_capacity(FLEET, caps,
                           scenarios=(Scenario(
                               "constant", workload.constant(RATES, 10)),),
                           config=ELASTIC)

    def test_stacked_config_leaves_are_batched(self):
        stacked = stack_capacities(capacity_scenario_library())
        assert stacked.policy_id.shape == (4,)
        assert stacked.cold_start_s.shape == (4,)
