"""Workflow-DAG routing tests.

Covers the routing acceptance criteria: ``Workflow`` flows through
jit/vmap as a pytree, the ``independent`` workflow reproduces the
pre-routing trajectories **bit-for-bit** under every registered policy,
requests are conserved end-to-end (exogenous in = completed + in-flight)
on the fan-out topologies, the JAX scan matches the numpy oracle under
routing, padded/stacked workflows match their unpadded originals, and the
(workflow × policy × scenario) sweep grid runs as one vmapped program.
"""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import allocator as alloc
from repro.core import routing, workload
from repro.core.agents import PAPER_ARRIVAL_RATES, pad_fleet, paper_fleet
from repro.core.reference_sim import simulate_numpy
from repro.core.routing import (
    Workflow,
    coordinator_star,
    hierarchical,
    independent,
    pad_workflow,
    pipeline_chain,
    stack_workflows,
    synthetic_workflow,
)
from repro.core.simulator import (
    METRIC_NAMES,
    SimConfig,
    run_policy,
    simulate,
    trace_metrics,
)
from repro.core.sweep import (
    Scenario,
    scenario_library,
    sweep,
    sweep_workflows,
    workflow_scenario_library,
)

FLEET = paper_fleet()
RATES = jnp.asarray(PAPER_ARRIVAL_RATES, jnp.float32)
ARR = workload.constant(RATES, 50)

TOPOLOGIES = (
    coordinator_star(4),
    pipeline_chain(4),
    hierarchical(4),
    synthetic_workflow(4, seed=3),
)


def _in_flight(tr, wf) -> float:
    """Backlog + routed-but-not-yet-arrived mass at the end of a trace."""
    pending = (np.asarray(tr.served[-1]) * np.asarray(wf.fan_out)) @ np.asarray(
        wf.route
    )
    return float(np.asarray(tr.queue[-1]).sum() + pending.sum())


class TestWorkflowPytree:
    def test_flatten_roundtrip(self):
        wf = hierarchical(4)
        leaves, treedef = jax.tree_util.tree_flatten(wf)
        assert len(leaves) == 4  # route + source + sink + fan_out
        back = jax.tree_util.tree_unflatten(treedef, leaves)
        assert back.name == wf.name
        np.testing.assert_array_equal(np.asarray(back.route), np.asarray(wf.route))

    def test_jit_passthrough(self):
        wf = coordinator_star(4)
        total = jax.jit(lambda w: w.route.sum())(wf)
        assert abs(float(total) - 1.0) < 1e-6

    def test_vmap_over_stacked_workflows(self):
        stacked = stack_workflows([independent(4), hierarchical(4)])
        rowsums = jax.vmap(lambda w: w.route.sum())(stacked)
        np.testing.assert_allclose(np.asarray(rowsums), [0.0, 3.0], atol=1e-6)

    def test_name_does_not_fragment_the_jit_cache(self):
        """Same-shape workflows must share one treedef (and so one compiled
        trace) regardless of their cosmetic name."""
        t1 = jax.tree_util.tree_structure(synthetic_workflow(4, seed=0))
        t2 = jax.tree_util.tree_structure(synthetic_workflow(4, seed=1))
        assert t1 == t2
        assert synthetic_workflow(4, seed=1).name == "synthetic_s1"

    def test_exit_fraction(self):
        wf = coordinator_star(4)
        np.testing.assert_allclose(
            np.asarray(wf.exit_fraction), [0.0, 1.0, 1.0, 1.0], atol=1e-6
        )


class TestGenerators:
    @pytest.mark.parametrize("wf", TOPOLOGIES + (independent(4),),
                             ids=lambda w: w.name)
    def test_valid(self, wf):
        wf.validate()
        route = np.asarray(wf.route)
        assert (route >= 0).all()
        assert (route.sum(axis=1) <= 1 + 1e-5).all()
        # sinks forward nothing
        assert (route.sum(axis=1) * np.asarray(wf.sink) < 1e-6).all()
        assert np.asarray(wf.source).sum() >= 1

    def test_independent_is_all_source_all_sink(self):
        wf = independent(4)
        np.testing.assert_array_equal(np.asarray(wf.route), 0.0)
        np.testing.assert_array_equal(np.asarray(wf.source), 1.0)
        np.testing.assert_array_equal(np.asarray(wf.sink), 1.0)

    def test_star_routes_only_from_coordinator(self):
        wf = coordinator_star(5, fan_out=3.0)
        route = np.asarray(wf.route)
        np.testing.assert_allclose(route[0], [0, 0.25, 0.25, 0.25, 0.25])
        np.testing.assert_array_equal(route[1:], 0.0)
        np.testing.assert_allclose(np.asarray(wf.fan_out), [3, 1, 1, 1, 1])

    def test_pipeline_is_a_chain(self):
        wf = pipeline_chain(4)
        route = np.asarray(wf.route)
        assert route[0, 1] == route[1, 2] == route[2, 3] == 1.0
        assert route.sum() == 3.0
        np.testing.assert_array_equal(np.asarray(wf.source), [1, 0, 0, 0])
        np.testing.assert_array_equal(np.asarray(wf.sink), [0, 0, 0, 1])

    def test_synthetic_is_a_dag_and_deterministic(self):
        a, b = synthetic_workflow(8, seed=5), synthetic_workflow(8, seed=5)
        np.testing.assert_array_equal(np.asarray(a.route), np.asarray(b.route))
        # strictly upper-triangular => acyclic
        assert np.allclose(np.tril(np.asarray(a.route)), 0.0)
        a.validate()

    def test_validate_rejects_superstochastic_rows(self):
        wf = Workflow("bad", jnp.full((2, 2), 0.8), jnp.ones(2), jnp.zeros(2),
                      jnp.ones(2))
        with pytest.raises(ValueError, match="sum to <= 1"):
            wf.validate()

    def test_validate_rejects_sourceless_workflows(self):
        wf = Workflow("bad", jnp.zeros((2, 2)), jnp.zeros(2), jnp.ones(2),
                      jnp.ones(2))
        with pytest.raises(ValueError, match="source"):
            wf.validate()

    def test_validate_rejects_forwarding_sinks(self):
        route = jnp.zeros((2, 2)).at[1, 0].set(0.5)
        wf = Workflow("bad", route, jnp.ones(2), jnp.ones(2), jnp.ones(2))
        with pytest.raises(ValueError, match="sink"):
            wf.validate()

    def test_size_guards(self):
        with pytest.raises(ValueError):
            coordinator_star(1)
        with pytest.raises(ValueError):
            hierarchical(2)

    def test_validate_rejects_cycles(self):
        """Critical-path metrics and engine routing assume a DAG."""
        route = jnp.asarray([[0.0, 1.0], [1.0, 0.0]])
        wf = Workflow("cycle", route, jnp.asarray([1.0, 0.0]), jnp.zeros(2),
                      jnp.ones(2))
        with pytest.raises(ValueError, match="acyclic"):
            wf.validate()
        # self-loops are cycles too
        route = jnp.asarray([[0.5, 0.5], [0.0, 0.0]])
        wf = Workflow("self_loop", route, jnp.ones(2), jnp.asarray([0.0, 1.0]),
                      jnp.ones(2))
        with pytest.raises(ValueError, match="acyclic"):
            wf.validate()


class TestIndependentIsBitForBitNoOp:
    """Acceptance criterion: the identity workflow must not change a single
    bit of any trajectory, for every registered policy."""

    @pytest.mark.parametrize("policy", alloc.policy_names())
    def test_trajectories_identical(self, policy):
        arr = workload.poisson(RATES, 60, jax.random.key(1))
        plain = simulate(policy, arr, FLEET)
        routed = simulate(policy, arr, FLEET, workflow=independent(4))
        for field in ("allocation", "served", "queue", "latency", "arrivals",
                      "completed"):
            np.testing.assert_array_equal(
                np.asarray(getattr(plain, field)),
                np.asarray(getattr(routed, field)),
                err_msg=f"{policy}/{field}",
            )

    def test_summary_metrics_identical(self):
        a = run_policy("adaptive", ARR, FLEET)
        b = run_policy("adaptive", ARR, FLEET, workflow=independent(4))
        assert a.avg_latency == b.avg_latency
        assert a.total_throughput == b.total_throughput
        assert b.sink_throughput == pytest.approx(b.total_throughput, rel=1e-6)


class TestConservation:
    """Exogenous in == completed at sinks + in-flight, on every conserving
    (fan_out=1) topology."""

    @pytest.mark.parametrize("wf", TOPOLOGIES, ids=lambda w: w.name)
    @pytest.mark.parametrize("policy", ("adaptive", "static_equal",
                                        "water_filling"))
    def test_constant_load(self, wf, policy):
        tr = simulate(policy, ARR, FLEET, workflow=wf)
        exo = float(np.asarray(tr.arrivals).sum())
        comp = float(np.asarray(tr.completed).sum())
        np.testing.assert_allclose(exo, comp + _in_flight(tr, wf), rtol=1e-4)

    @hypothesis.given(
        rates=st.lists(st.floats(0, 300), min_size=4, max_size=4),
        policy=st.sampled_from(("adaptive", "throughput_greedy", "round_robin")),
        topo=st.sampled_from(range(len(TOPOLOGIES))),
    )
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_randomized(self, rates, policy, topo):
        wf = TOPOLOGIES[topo]
        arr = workload.constant(jnp.asarray(rates, jnp.float32), 30)
        tr = simulate(policy, arr, FLEET, workflow=wf)
        exo = float(np.asarray(tr.arrivals).sum())
        comp = float(np.asarray(tr.completed).sum())
        np.testing.assert_allclose(
            exo, comp + _in_flight(tr, wf), rtol=1e-3, atol=0.5
        )

    def test_fan_out_amplifies(self):
        """fan_out=2 at the coordinator must double the forwarded mass per
        served request (the star forwards everything the coordinator
        serves), so conservation picks up the amplification term."""
        one = simulate("adaptive", ARR, FLEET, workflow=coordinator_star(4))
        two = simulate("adaptive", ARR, FLEET,
                       workflow=coordinator_star(4, fan_out=2.0))
        routed1 = float(np.asarray(one.served[:, 0]).sum())
        routed2 = 2.0 * float(np.asarray(two.served[:, 0]).sum())
        assert routed2 > 1.5 * routed1
        # amplified traffic leaves more work in the system
        assert float(np.asarray(two.queue[-1]).sum()) >= \
            float(np.asarray(one.queue[-1]).sum())

    @pytest.mark.parametrize("policy", ("adaptive", "water_filling",
                                        "throughput_greedy"))
    def test_misrouted_mass_closes_the_balance(self, policy):
        """Routing into padded slots leaks mass out of the conserving
        balance — the ``misrouted`` trace field must account for every
        unit of it: exogenous in == completed + misrouted + in-flight
        (with the final-step forwarded mass masked to active slots, since
        mass routed into padding is recorded as misrouted in the same
        step it is forwarded)."""
        padded_fleet = pad_fleet(FLEET, 8)
        wf = pipeline_chain(8)  # route[3, 4] forwards into padding
        arr_p = jnp.pad(ARR, ((0, 0), (0, 4)))
        tr = simulate(policy, arr_p, padded_fleet, workflow=wf)
        mis = np.asarray(tr.misrouted)
        assert mis.sum() > 0, "stage 3 must leak into the padded slot"
        # misrouted mass only ever appears on inactive slots
        assert (mis[:, :4] == 0.0).all()
        exo = float(np.asarray(tr.arrivals).sum())
        comp = float(np.asarray(tr.completed).sum())
        pending = (np.asarray(tr.served[-1]) * np.asarray(wf.fan_out)) \
            @ np.asarray(wf.route)
        in_flight = float(np.asarray(tr.queue[-1]).sum()
                          + (pending * np.asarray(padded_fleet.active)).sum())
        np.testing.assert_allclose(
            exo, comp + mis.sum() + in_flight, rtol=1e-4
        )


class TestOracleParity:
    """JAX scan vs numpy oracle under routing, full policy registry."""

    @pytest.mark.parametrize("wf", TOPOLOGIES, ids=lambda w: w.name)
    @pytest.mark.parametrize("policy", alloc.policy_names())
    def test_scan_matches_oracle(self, wf, policy):
        arr = workload.constant(RATES, 40)
        tr = simulate(policy, arr, FLEET, workflow=wf)
        ref = simulate_numpy(policy, np.asarray(arr), FLEET, workflow=wf)
        for field in ("allocation", "served", "queue", "latency", "completed"):
            np.testing.assert_allclose(
                np.asarray(getattr(tr, field), np.float64), ref[field],
                rtol=2e-4, atol=5e-3, err_msg=f"{wf.name}/{policy}/{field}",
            )


class TestPaddingConsistency:
    def test_pad_workflow_keeps_real_routing(self):
        wf = pad_workflow(hierarchical(4), 7)
        wf.validate()
        assert wf.num_agents == 7
        np.testing.assert_array_equal(
            np.asarray(wf.route)[:4, :4], np.asarray(hierarchical(4).route)
        )
        np.testing.assert_array_equal(np.asarray(wf.route)[4:], 0.0)
        np.testing.assert_array_equal(np.asarray(wf.route)[:, 4:], 0.0)
        np.testing.assert_array_equal(np.asarray(wf.source)[4:], 0.0)

    def test_pad_below_size_raises(self):
        with pytest.raises(ValueError):
            pad_workflow(hierarchical(4), 3)

    @pytest.mark.parametrize("wf", TOPOLOGIES, ids=lambda w: w.name)
    def test_padded_simulation_matches_unpadded(self, wf):
        """pad_fleet + pad_workflow together must reproduce the unpadded
        trajectories on the real slots and keep padding perfectly inert."""
        padded_fleet = pad_fleet(FLEET, 9)
        padded_wf = pad_workflow(wf, 9)
        arr_p = jnp.pad(ARR, ((0, 0), (0, 5)))
        for policy in ("adaptive", "water_filling"):
            a = simulate(policy, ARR, FLEET, workflow=wf)
            b = simulate(policy, arr_p, padded_fleet, workflow=padded_wf)
            for field in ("served", "queue", "completed"):
                np.testing.assert_allclose(
                    np.asarray(getattr(a, field)),
                    np.asarray(getattr(b, field))[:, :4],
                    rtol=2e-3, atol=5e-2, err_msg=f"{wf.name}/{policy}/{field}",
                )
            assert (np.asarray(b.served)[:, 4:] == 0.0).all()
            assert (np.asarray(b.queue)[:, 4:] == 0.0).all()

    def test_route_into_padded_slot_is_dropped(self):
        """A workflow whose route targets an inactive slot must not wake
        the padding: the endogenous gate drops the misrouted mass, so the
        padded slot stays at zero queue/served and active agents keep
        their capacity."""
        padded_fleet = pad_fleet(FLEET, 8)
        wf = pipeline_chain(8)  # route[3, 4] forwards into padding
        arr_p = jnp.pad(ARR, ((0, 0), (0, 4)))
        tr = simulate("water_filling", arr_p, padded_fleet, workflow=wf)
        assert (np.asarray(tr.queue)[:, 4:] == 0.0).all()
        assert (np.asarray(tr.served)[:, 4:] == 0.0).all()
        assert (np.asarray(tr.allocation)[:, 4:] == 0.0).all()

    def test_stack_workflows_pads_to_widest(self):
        stacked = stack_workflows([pipeline_chain(3), hierarchical(5)])
        assert stacked.num_agents == 5
        assert np.asarray(stacked.route).shape == (2, 5, 5)
        np.testing.assert_allclose(
            np.asarray(stacked.source).sum(axis=1), [1.0, 1.0]
        )


class TestWorkflowMetrics:
    def test_sink_throughput_counts_exits_only(self):
        wf = pipeline_chain(4)
        tr = simulate("static_equal", ARR, FLEET, workflow=wf)
        vec, _, _, _ = trace_metrics(tr, FLEET.active, wf, config=SimConfig())
        m = dict(zip(METRIC_NAMES, np.asarray(vec)))
        # only the tail stage exits; total throughput counts every stage
        assert m["sink_throughput"] < m["total_throughput"]
        per_step_exits = np.asarray(tr.completed).sum(axis=1)
        np.testing.assert_allclose(
            m["sink_throughput"], per_step_exits.mean(), rtol=1e-5
        )

    def test_critical_path_exceeds_max_stage_latency_on_chain(self):
        wf = pipeline_chain(4)
        tr = simulate("static_equal", ARR, FLEET, workflow=wf)
        vec, per_lat, _, _ = trace_metrics(tr, FLEET.active, wf, config=SimConfig())
        m = dict(zip(METRIC_NAMES, np.asarray(vec)))
        # the chain's critical path is the sum of all stage latencies
        np.testing.assert_allclose(
            m["critical_path_latency"], np.asarray(per_lat).sum(), rtol=1e-4
        )
        assert m["critical_path_latency"] >= np.asarray(per_lat).max() - 1e-5

    def test_per_agent_queue_exposed(self):
        s = run_policy("adaptive", ARR, FLEET, workflow=pipeline_chain(4))
        assert len(s.per_agent_queue) == 4
        assert all(q >= 0 for q in s.per_agent_queue)


class TestSweepWorkflows:
    @pytest.fixture(scope="class")
    def grid(self):
        scenarios = scenario_library(PAPER_ARRIVAL_RATES, num_steps=30, seed=0)
        workflows = workflow_scenario_library(4, seed=0)
        return workflows, scenarios, sweep_workflows(
            FLEET, workflows, scenarios, keep_traces=True
        )

    def test_grid_shape(self, grid):
        workflows, scenarios, res = grid
        K, P, W = len(workflows), len(alloc.policy_names()), len(scenarios)
        assert K >= 3  # acceptance: >= 3 topologies in one program
        assert res.metrics.shape == (K, P, W, len(METRIC_NAMES))
        assert np.isfinite(res.metrics).all()
        assert res.workflow_names == tuple(w.name for w in workflows)
        assert res.per_agent_queue.shape == (K, P, W, 4)

    def test_independent_row_matches_plain_sweep(self, grid):
        workflows, scenarios, res = grid
        plain = sweep(FLEET, scenarios)
        k = res.workflow_names.index("independent")
        np.testing.assert_allclose(
            res.metrics[k], plain.metrics, rtol=1e-4, atol=1e-3
        )

    def test_table_and_best_carry_workflow_axis(self, grid):
        workflows, scenarios, res = grid
        table = res.table()
        assert table.columns[0] == "workflow"
        assert len(table.rows) == (
            len(workflows) * len(res.policy_names) * len(scenarios)
        )
        best = table.best("critical_path_latency")
        assert set(best) == {
            f"{wn}/{sc}" for wn in res.workflow_names for sc in res.scenario_names
        }

    def test_summary_requires_workflow_on_batched_grid(self, grid):
        _, _, res = grid
        with pytest.raises(ValueError):
            res.summary("adaptive", "constant")
        with pytest.raises(ValueError):
            res.summary("adaptive", "constant", fleet="independent")
        s = res.summary("adaptive", "constant", workflow="hierarchical")
        assert np.isfinite(s.critical_path_latency)

    def test_padded_grid_matches_unpadded(self):
        """Acceptance: mask-consistent padded/stacked results — the same
        workflow grid on a padded fleet + padded workflows reproduces the
        unpadded metrics."""
        scenarios = scenario_library(PAPER_ARRIVAL_RATES, num_steps=25, seed=0)
        workflows = workflow_scenario_library(4, seed=0)
        res = sweep_workflows(FLEET, workflows, scenarios)

        padded_fleet = pad_fleet(FLEET, 6)
        padded_wfs = [pad_workflow(w, 6) for w in workflows]
        padded_scen = tuple(
            Scenario(s.name, jnp.pad(s.arrivals, ((0, 0), (0, 2))))
            for s in scenarios
        )
        res_p = sweep_workflows(padded_fleet, padded_wfs, padded_scen)
        np.testing.assert_allclose(
            res.metrics, res_p.metrics, rtol=2e-3, atol=5e-2
        )

    def test_workflow_width_mismatch_raises(self):
        with pytest.raises(ValueError, match="agents"):
            sweep_workflows(FLEET, [hierarchical(6)],
                            scenario_library(PAPER_ARRIVAL_RATES, num_steps=5))

    def test_batched_workflow_rejected_by_unbatched_entry_points(self):
        """A stacked workflow must only flow through sweep_workflows' vmap;
        simulate() would die deep inside the scan otherwise."""
        stacked = stack_workflows([independent(4), hierarchical(4)])
        with pytest.raises(ValueError, match="batched"):
            simulate("adaptive", ARR, FLEET, workflow=stacked)

    def test_duplicate_workflow_names_raise(self):
        with pytest.raises(ValueError, match="unique"):
            sweep_workflows(FLEET, [independent(4), independent(4)],
                            scenario_library(PAPER_ARRIVAL_RATES, num_steps=5))
