"""Device-mesh sharding invariants — ``core/sharding.py`` + shard_map.

Three layers of coverage:

* **Pure unit tests** (any device count): 2D and near-cubic 3D mesh
  factorization, policy-axis (dp) resolution, padding semantics, mesh
  caching, the ``REPRO_SWEEP_SHARD`` escape hatch, and the
  backend-initialization guard on ``force_host_device_count``.
* **In-process multi-device tests** — run when the interpreter already
  sees >= 2 devices (CI's dedicated step sets ``XLA_FLAGS=--xla_force_
  host_platform_device_count=8``): (a) the sharded streaming grid
  matches the unsharded trace oracle for the FULL policy registry, (b)
  sharded metrics are **bit-identical** to unsharded for all four sweep
  entry points — including non-divisible axis sizes, where the padded
  rows must strip away without a trace (cells are independent and the
  shard body is the very same ``_stream_grid`` the single-device jit
  runs, so exact equality is the contract, not a tolerance), (c) arrivals
  donation does not poison second calls, (d) the 3D policy axis
  (``shard="3d"`` / ``REPRO_SWEEP_POLICY_DEVICES``) and the in-scan
  synthesized path are each bit-identical to their unsharded twins.
* **Subprocess fallback** (single-device runs): one forced-8-device child
  re-runs the entry-point grids sharded (2D and 3D) and the parent
  compares against its own single-device references.
"""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sharding
from repro.core.agents import synthetic_fleet
from repro.core.sweep import (
    scenario_library,
    sweep,
    sweep_capacity,
    sweep_fleets,
    sweep_workflows,
)
from repro.core import workload
from repro.core.workload import synthetic_rates

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

NUM_STEPS = 12
POLICIES = ("static_equal", "adaptive", "water_filling")
# Non-divisible on purpose: 5 fleets never divide a 2- or 8-wide mesh axis.
ODD_FLEET_SIZES = (2, 3, 4, 5, 3)

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(covered by the subprocess variant on single-device runs)",
)


# -- pure unit layer ---------------------------------------------------------


def test_mesh_shape_near_square_grid_major():
    assert sharding.mesh_shape(1) == (1, 1)
    assert sharding.mesh_shape(2) == (1, 2)
    assert sharding.mesh_shape(4) == (2, 2)
    assert sharding.mesh_shape(6) == (2, 3)
    assert sharding.mesh_shape(7) == (1, 7)   # prime: all on the grid axis
    assert sharding.mesh_shape(8) == (2, 4)
    for n in range(1, 33):
        dd, dg = sharding.mesh_shape(n)
        assert dd * dg == n and dd <= dg
    with pytest.raises(ValueError):
        sharding.mesh_shape(0)


def test_pad_axis_repeats_row_zero_and_noops_when_divisible():
    x = jnp.arange(12.0).reshape(3, 4)
    assert sharding.pad_axis(x, 0, 3) is x
    padded = sharding.pad_axis(x, 0, 4)
    assert padded.shape == (4, 4)
    np.testing.assert_array_equal(padded[:3], x)
    np.testing.assert_array_equal(padded[3], x[0])
    padded1 = sharding.pad_axis(x, 1, 6)
    assert padded1.shape == (3, 6)
    np.testing.assert_array_equal(padded1[:, 4:], np.stack([x[:, 0]] * 2, 1))


def test_pad_tree_axis_pads_every_leaf_and_keeps_aux():
    fleet = synthetic_fleet(3, seed=0)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, x, x]), fleet
    )  # (3, N) leaves
    padded = sharding.pad_tree_axis(stacked, 0, 2)
    assert padded.priority.shape == (4, 3)
    np.testing.assert_array_equal(padded.priority[3], stacked.priority[0])
    assert padded.names == stacked.names  # static aux untouched


def test_mesh_shape_3d_near_cubic_policy_minor():
    assert sharding.mesh_shape_3d(1) == (1, 1, 1)
    assert sharding.mesh_shape_3d(4) == (2, 2, 1)   # 2^3 > 4: dp stays 1
    assert sharding.mesh_shape_3d(7) == (1, 7, 1)   # prime: all on grid
    assert sharding.mesh_shape_3d(8) == (2, 2, 2)
    assert sharding.mesh_shape_3d(16) == (2, 4, 2)
    assert sharding.mesh_shape_3d(27) == (3, 3, 3)
    assert sharding.mesh_shape_3d(64) == (4, 4, 4)
    for n in range(1, 33):
        dd, dg, dp = sharding.mesh_shape_3d(n)
        assert dd * dg * dp == n and dd <= dg and dp ** 3 <= n
    with pytest.raises(ValueError):
        sharding.mesh_shape_3d(0)


def test_grid_mesh_is_cached():
    assert sharding.grid_mesh() is sharding.grid_mesh()
    dd, dg = sharding.mesh_shape(jax.device_count())
    # The mesh always carries the policy axis; dp=1 is the 2D layout
    # (arrays never shard over a size-1 axis, so pre-3D programs are
    # unchanged by construction).
    assert sharding.grid_mesh().shape == {"data": dd, "grid": dg, "policy": 1}


def test_grid_mesh_rejects_non_divisible_policy_axis():
    with pytest.raises(ValueError, match="must divide"):
        sharding.grid_mesh(num_devices=8, policy_devices=3)


def test_policy_mesh_devices_resolution(monkeypatch):
    monkeypatch.delenv(sharding.POLICY_ENV, raising=False)
    monkeypatch.delenv(sharding.MESH3D_ENV, raising=False)
    monkeypatch.delenv(sharding.SHARD_ENV, raising=False)
    # Pretend 8 devices so resolution logic is exercised on any host.
    monkeypatch.setattr(
        sharding, "should_shard", lambda flag=None: flag is not False
    )
    monkeypatch.setattr(sharding.jax, "device_count", lambda: 8)
    assert sharding.policy_mesh_devices(True) == 1       # default: 2D layout
    assert sharding.policy_mesh_devices("3d") == 2       # near-cubic 8 -> dp=2
    monkeypatch.setenv(sharding.MESH3D_ENV, "1")
    assert sharding.policy_mesh_devices(True) == 2       # global 3D switch
    monkeypatch.setenv(sharding.POLICY_ENV, "4")
    assert sharding.policy_mesh_devices(True) == 4       # explicit dp wins
    monkeypatch.setenv(sharding.POLICY_ENV, "3")
    with pytest.raises(ValueError, match="must divide"):
        sharding.policy_mesh_devices(True)
    assert sharding.policy_mesh_devices(False) == 1      # sharding off


def test_should_shard_resolution(monkeypatch):
    monkeypatch.delenv(sharding.SHARD_ENV, raising=False)
    assert sharding.should_shard(False) is False  # flag always wins
    assert sharding.should_shard(None) == (jax.device_count() > 1)
    assert sharding.should_shard(True) == (jax.device_count() > 1)
    monkeypatch.setenv(sharding.SHARD_ENV, "0")
    assert not sharding.shard_env_enabled()
    assert sharding.should_shard(True) is False   # escape hatch beats flag
    monkeypatch.setenv(sharding.SHARD_ENV, "1")
    assert sharding.shard_env_enabled()


def test_force_host_device_count_refuses_live_backend():
    jax.devices()  # ensure the backend is initialized
    with pytest.raises(RuntimeError, match="already initialized"):
        sharding.force_host_device_count(8)


def test_host_device_env_sets_flag_and_strips_stale_one():
    env = sharding.host_device_env(4, base_env={"XLA_FLAGS": "--foo=1"})
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    assert "--foo=1" in env["XLA_FLAGS"]
    assert env["JAX_PLATFORMS"] == "cpu"
    again = sharding.host_device_env(2, base_env=env)
    assert "device_count=4" not in again["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=2" in again["XLA_FLAGS"]


# -- grid helpers ------------------------------------------------------------


def _fleet_grid(shard, sizes=ODD_FLEET_SIZES, stream=None, policies=POLICIES,
                synthesize=None, block_size=None):
    fleets = [synthetic_fleet(n, seed=i) for i, n in enumerate(sizes)]
    return sweep_fleets(
        fleets, num_steps=NUM_STEPS, seed=0, policies=policies, shard=shard,
        stream=stream, synthesize=synthesize, block_size=block_size,
    ).metrics


def _entry_grids(shard, synthesize=None, block_size=None):
    """Metrics from all four entry points under one shard setting.

    ``synthesize=True`` swaps the workload column to ``WorkloadSpec`` rows
    (in-scan synthesis when streaming) — same grid values bit-for-bit, per
    the synthesis parity contract.  ``block_size`` threads the streaming
    time-block B through, also bit-neutral by contract."""
    fleet = synthetic_fleet(4, seed=0)
    rates = synthetic_rates(4, seed=0)
    if synthesize:
        scenarios = workload.scenario_specs(rates, num_steps=NUM_STEPS)
    else:
        scenarios = scenario_library(rates, num_steps=NUM_STEPS)
    return {
        "sweep": sweep(fleet, scenarios, policies=POLICIES, shard=shard,
                       synthesize=synthesize, block_size=block_size).metrics,
        "fleets": _fleet_grid(shard, synthesize=synthesize,
                              block_size=block_size),
        "workflows": sweep_workflows(
            fleet, num_steps=NUM_STEPS, policies=POLICIES, shard=shard,
            synthesize=synthesize, block_size=block_size,
        ).metrics,
        "capacity": sweep_capacity(
            fleet, num_steps=NUM_STEPS, policies=POLICIES, shard=shard,
            synthesize=synthesize, block_size=block_size,
        ).metrics,
    }


# -- in-process multi-device layer -------------------------------------------


@multi_device
def test_sharded_streaming_matches_trace_oracle_full_registry():
    """(a) The 2D shard_map streaming grid against the unsharded
    trace-materializing oracle, every registered policy."""
    streamed = _fleet_grid(shard=True, stream=True, policies=None)
    oracle = _fleet_grid(shard=False, stream=False, policies=None)
    np.testing.assert_allclose(streamed, oracle, rtol=1e-3, atol=1e-3)


@multi_device
def test_all_entry_points_sharded_bit_identical_to_unsharded():
    """(b) Exact equality, all four entry points: the shard body is the
    same ``_stream_grid`` the single-device jit runs, cells never
    interact, and padded rows must strip without residue."""
    sharded, unsharded = _entry_grids(True), _entry_grids(False)
    for name in sharded:
        np.testing.assert_array_equal(
            sharded[name], unsharded[name], err_msg=name
        )


@multi_device
def test_non_divisible_fleet_axis_padding_is_invisible():
    """5 fleets on a (2, 4) mesh: both sharded axes need padding; the
    result must still be bit-identical to the unsharded grid."""
    assert len(ODD_FLEET_SIZES) % jax.device_count() != 0
    np.testing.assert_array_equal(
        _fleet_grid(shard=True), _fleet_grid(shard=False)
    )


@multi_device
def test_trace_oracle_sharded_fleet_axis_padding_is_invisible():
    """The trace kernel's padded layout-hint path (``_shard_fleet_axis``)
    on a non-divisible fleet count — the old silent-replication fallback's
    replacement — must also strip cleanly."""
    np.testing.assert_allclose(
        _fleet_grid(shard=True, stream=False),
        _fleet_grid(shard=False, stream=False),
        rtol=1e-5, atol=1e-6,
    )


@multi_device
def test_donation_does_not_poison_second_calls():
    """(c) ``_stream_grid_sharded`` donates its arrivals block; entry
    points must rebuild it per call, so back-to-back sweeps agree."""
    first = _entry_grids(True)
    second = _entry_grids(True)
    for name in first:
        np.testing.assert_array_equal(first[name], second[name], err_msg=name)


@multi_device
def test_3d_policy_axis_bit_identical_to_unsharded():
    """(d) ``shard="3d"`` splits the policy stack over the mesh's third
    axis (8 devices -> dp=2); the blocked ``lax.switch`` dispatch runs the
    same per-policy branches as the flat stack, so exact equality holds
    for all four entry points."""
    three_d, unsharded = _entry_grids("3d"), _entry_grids(False)
    for name in three_d:
        np.testing.assert_array_equal(
            three_d[name], unsharded[name], err_msg=name
        )


@multi_device
def test_policy_devices_env_override_bit_identical(monkeypatch):
    """Explicit dp via ``REPRO_SWEEP_POLICY_DEVICES`` — dp=4 on 8 devices
    is a (1, 2, 4) mesh and pads the 3-policy stack to 4 rows; the padded
    policy row must strip without residue."""
    monkeypatch.setenv(sharding.POLICY_ENV, "4")
    grids = _fleet_grid(shard=True)
    monkeypatch.delenv(sharding.POLICY_ENV)
    np.testing.assert_array_equal(grids, _fleet_grid(shard=False))


@multi_device
def test_synthesized_sharded_bit_identical_to_unsharded():
    """(d) In-scan synthesis under the sharded grid, 2D and 3D: scenario
    rows are ``WorkloadSpec`` pytrees (the spec stack shards like the
    arrivals block it replaces), no (S, N) slab ever materializes, and the
    metrics must equal the unsharded synthesized grid exactly."""
    reference = _entry_grids(False, synthesize=True)
    for shard in (True, "3d"):
        grids = _entry_grids(shard, synthesize=True)
        for name in grids:
            np.testing.assert_array_equal(
                grids[name], reference[name], err_msg=f"{shard}:{name}"
            )


@multi_device
def test_sharded_block_size_bit_identical():
    """The time-blocked two-level scan under ``shard_map``: ``block_size``
    is a pure schedule change inside each device's shard body, so B=5
    (forcing the masked tail at S=12) must match both the sharded B=1 grid
    and the unsharded blocked grid bit-for-bit — materialized and in-scan
    synthesized arms alike."""
    base = _entry_grids(True)
    blocked = _entry_grids(True, block_size=5)
    unsharded_blocked = _entry_grids(False, block_size=5)
    for name in base:
        np.testing.assert_array_equal(
            blocked[name], base[name], err_msg=f"B=5 vs B=1 sharded: {name}"
        )
        np.testing.assert_array_equal(
            blocked[name], unsharded_blocked[name],
            err_msg=f"sharded vs unsharded at B=5: {name}",
        )
    synth_ref = _entry_grids(False, synthesize=True)
    synth_blocked = _entry_grids(True, synthesize=True, block_size=5)
    for name in synth_blocked:
        np.testing.assert_array_equal(
            synth_blocked[name], synth_ref[name],
            err_msg=f"synthesized sharded B=5: {name}",
        )


@multi_device
def test_escape_hatch_forces_unsharded_path(monkeypatch):
    monkeypatch.setenv(sharding.SHARD_ENV, "0")
    hatch = _fleet_grid(shard=None)
    monkeypatch.delenv(sharding.SHARD_ENV)
    np.testing.assert_array_equal(hatch, _fleet_grid(shard=False))


# -- subprocess fallback (single-device hosts) -------------------------------


_CHILD = """
import numpy as np
import jax
assert jax.device_count() == 8, jax.devices()
import tests.test_sharding as t
grids = t._entry_grids(True)
odd = t._fleet_grid(shard=True)
odd3d = t._fleet_grid(shard="3d")
odd_blocked = t._fleet_grid(shard=True, block_size=5)
odd_blocked_synth = t._fleet_grid(shard=True, synthesize=True, block_size=5)
np.savez({out!r}, odd=odd, odd3d=odd3d, odd_blocked=odd_blocked,
         odd_blocked_synth=odd_blocked_synth, **grids)
"""


@pytest.mark.skipif(
    jax.device_count() >= 2,
    reason="in-process variant already exercises the multi-device path",
)
def test_sharded_8_device_subprocess_matches_single_device():
    references = _entry_grids(False)
    references["odd"] = _fleet_grid(shard=False)
    references["odd3d"] = references["odd"]  # same unsharded reference
    # Blocked sharded grids against the *unblocked* unsharded references:
    # block_size is bit-neutral, so B=5 under the forced-8 mesh must land
    # on the same values.
    references["odd_blocked"] = references["odd"]
    references["odd_blocked_synth"] = _fleet_grid(
        shard=False, synthesize=True
    )
    root = os.path.dirname(SRC)
    env = sharding.host_device_env(8)
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, root, env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "grids.npz")
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD.format(out=out)], env=env,
            cwd=root, capture_output=True, text=True, timeout=600,
        )
        assert proc.returncode == 0, (proc.stdout, proc.stderr)
        sharded = np.load(out)
        for name in references:
            np.testing.assert_allclose(
                sharded[name], references[name], rtol=1e-5, atol=1e-6,
                err_msg=name,
            )
