"""Serving-engine integration: the paper's allocator driving real models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import allocator as alloc
from repro.core import routing
from repro.core.agents import AgentSpec, Fleet
from repro.core.capacity import billing_cost, capacity_config
from repro.models.model import build_model
from repro.serving.engine import AgentRuntime, FleetEngine


def _fleet_2():
    return Fleet.from_specs([
        AgentSpec("fast", 100.0, 100.0, 0.2, 1),
        AgentSpec("slow", 500.0, 20.0, 0.3, 2),
    ])


def _engine(policy="adaptive", budget_tokens=32, **kwargs):
    fleet = _fleet_2()
    key = jax.random.key(0)
    rts = {}
    for name, arch in (("fast", "minitron-4b"), ("slow", "mamba2-370m")):
        cfg = get_config(arch, reduced=True)
        api = build_model(cfg)
        rts[name] = AgentRuntime(name, api, api.init(key), max_len=48, batch_slots=2)
    return FleetEngine(fleet, rts, policy=policy, budget_tokens=budget_tokens, **kwargs)


@pytest.mark.parametrize("policy", ["adaptive", "static_equal", "round_robin",
                                    "water_filling", "predictive"])
def test_engine_completes_requests(policy):
    eng = _engine(policy)
    rng = np.random.default_rng(0)
    for t in range(10):
        eng.submit("fast", rng.integers(0, 50, 6), max_new_tokens=3)
        if t % 2 == 0:
            eng.submit("slow", rng.integers(0, 50, 6), max_new_tokens=3)
        eng.step()
    m = eng.metrics()
    assert m["completed"] > 0
    assert m["tokens_generated"] >= m["completed"] * 3


def test_every_registered_policy_dispatches_in_engine():
    """Regression: every POLICY_NAMES entry (incl. throughput_greedy, which
    used to raise ValueError here) must run end-to-end through the engine."""
    eng = _engine()
    rng = np.random.default_rng(3)
    for policy in alloc.policy_names():
        eng.policy = policy
        eng.submit("fast", rng.integers(0, 50, 4), 2)
        eng.step()
    assert eng.tick == len(alloc.policy_names())
    for h in eng.history:
        assert sum(h["allocation"]) <= 1.0 + 1e-4
        assert min(h["allocation"]) >= -1e-6


def test_engine_rejects_unknown_policy():
    with pytest.raises(ValueError, match="registered policies"):
        _engine("not_a_policy")


def test_engine_ema_seeds_then_updates_with_configured_alpha():
    """Same EMA semantics as the simulator's scan: the first observation
    seeds the forecast (no drift from a zero seed), later ticks apply one
    ``ema_forecast`` update each."""
    eng = _engine("predictive", budget_tokens=16, ema_alpha=0.5)
    eng.submit("fast", np.arange(4), 1)
    eng.step()
    # seeded with the first observation, not updated from zeros
    np.testing.assert_allclose(eng._ema, [1.0, 0.0], atol=1e-6)
    eng.step()
    # one update away from the seed: 0.5 * 0 + 0.5 * 1
    np.testing.assert_allclose(eng._ema, [0.5, 0.0], atol=1e-6)


def test_engine_tick0_allocation_matches_dispatch_with_seeded_ema():
    """Regression: tick-0 allocation must equal ``alloc.dispatch`` with
    ``lam_ema == lam`` — the engine used to run the EMA update against a
    zero seed, so EMA-driven policies drifted from the simulator at t=0."""
    eng = _engine("predictive", budget_tokens=16)
    eng.submit("fast", np.arange(4), 1)
    eng.submit("fast", np.arange(4), 1)
    eng.step()
    lam = jnp.asarray([2.0, 0.0], jnp.float32)
    q = jnp.asarray([2.0, 0.0], jnp.float32)
    expect = np.asarray(
        alloc.dispatch("predictive", jnp.asarray(0), lam, lam, q,
                       eng.fleet, eng.g_total)
    )
    np.testing.assert_allclose(eng.history[0]["allocation"], expect, atol=1e-6)
    np.testing.assert_allclose(eng._ema, np.asarray(lam), atol=1e-6)


class TestWorkflowRouting:
    def test_finished_requests_flow_downstream(self):
        """coordinator_star(2): every request finished at the coordinator
        spawns one child at the specialist, prompt = generated tokens."""
        wf = routing.coordinator_star(2)
        eng = _engine("adaptive", workflow=wf)
        rng = np.random.default_rng(0)
        for t in range(14):
            if t < 5:
                eng.submit("fast", rng.integers(0, 50, 6), max_new_tokens=3)
            eng.step()
        m = eng.metrics()
        assert m["routed_requests"] > 0
        slow_done = [r for r in eng.completed if r.agent == "slow"]
        assert slow_done, "specialist never completed a routed request"
        by_id = {r.id: r for r in eng.completed}
        for r in slow_done:
            assert r.parent_id >= 0
            parent = by_id[r.parent_id]
            assert parent.agent == "fast"
            # children arrive the tick after the parent finished
            assert r.arrival_tick == parent.finish_tick + 1
            np.testing.assert_array_equal(r.prompt, np.asarray(parent.tokens_out))
        assert m["sink_completed"] == len(slow_done)
        assert m["end_to_end_latency_ticks"] >= m["avg_latency_ticks"]

    def test_fractional_credit_accumulates(self):
        """With route weight 1/2 per edge, children spawn every second
        finished request — deterministically, with no mass lost."""
        wf = routing.coordinator_star(3)  # route[0, 1:] = 0.5 each
        fleet = Fleet.from_specs([
            AgentSpec("fast", 100.0, 100.0, 0.2, 1),
            AgentSpec("slow", 500.0, 20.0, 0.3, 2),
            AgentSpec("slow2", 500.0, 20.0, 0.3, 2),
        ])
        key = jax.random.key(0)
        rts = {}
        for name, arch in (("fast", "minitron-4b"), ("slow", "mamba2-370m"),
                           ("slow2", "mamba2-370m")):
            cfg = get_config(arch, reduced=True)
            api = build_model(cfg)
            rts[name] = AgentRuntime(name, api, api.init(key), max_len=48,
                                     batch_slots=2)
        eng = FleetEngine(fleet, rts, policy="adaptive", budget_tokens=32,
                          workflow=wf)
        rng = np.random.default_rng(1)
        for t in range(16):
            if t < 6:
                eng.submit("fast", rng.integers(0, 50, 5), max_new_tokens=3)
            eng.step()
        done_fast = [r for r in eng.completed if r.agent == "fast"]
        m = eng.metrics()
        # every two finished coordinator requests spawn one child per edge
        expect = 2 * (len(done_fast) // 2)
        assert m["routed_requests"] in (expect, expect + 1, expect + 2)

    def test_workflow_width_mismatch_raises(self):
        with pytest.raises(ValueError, match="agents"):
            _engine("adaptive", workflow=routing.coordinator_star(3))

    def test_exogenous_submit_to_non_source_raises(self):
        """The simulator zeroes exogenous arrivals at non-source agents;
        the engine must enforce the same contract instead of silently
        serving traffic the model says cannot exist."""
        eng = _engine("adaptive", workflow=routing.coordinator_star(2))
        with pytest.raises(ValueError, match="source"):
            eng.submit("slow", np.arange(4), 2)
        # sources still accept outside traffic
        eng.submit("fast", np.arange(4), 2)


class TestWarmPoolGating:
    """The engine analogue of the simulator's capacity layer: the warm
    pool gates the per-tick token budget."""

    def test_scale_to_zero_stops_serving_and_billing(self):
        cap = capacity_config("scale_to_zero", keep_alive_s=2.0,
                              target_rate_per_instance=4.0,
                              backlog_per_instance=4.0)
        eng = _engine("adaptive", capacity=cap, num_gpus=4.0)
        rng = np.random.default_rng(0)
        for _ in range(3):
            eng.submit("fast", rng.integers(0, 50, 4), 2)
            eng.step()
        for _ in range(12):     # drain, then idle past the keep-alive
            eng.step()
        warm = [h["warm"] for h in eng.history]
        assert warm[0] >= 1.0
        assert warm[-1] == 0.0
        # a sleeping pool allocates nothing and decodes nothing
        tail = eng.history[-1]
        assert sum(tail["allocation"]) == 0.0
        assert sum(tail["decode_tokens"]) == 0.0
        m = eng.metrics()
        assert m["warm_instance_ticks"] < eng.tick  # cheaper than always-on
        assert abs(m["cost_usd"]
                   - billing_cost(m["warm_instance_ticks"],
                                  eng.price_per_hour)) < 1e-12

    def test_reactive_pool_expands_token_budget(self):
        """With warm > 1 the fleet-wide allocation may exceed 1.0 — the
        per-instance budget_tokens scales with the pool."""
        cap = capacity_config("reactive", target_rate_per_instance=1.0,
                              backlog_per_instance=2.0, min_instances=1.0)
        eng = _engine("water_filling", capacity=cap, num_gpus=3.0)
        rng = np.random.default_rng(1)
        for _ in range(6):
            for _ in range(3):
                eng.submit("fast", rng.integers(0, 50, 4), 2)
            eng.step()
        warm = [h["warm"] for h in eng.history]
        assert max(warm) > 1.0
        assert max(warm) <= 3.0 + 1e-9
        for h in eng.history:   # budget gated by the tick's warm pool
            assert sum(h["allocation"]) <= h["warm"] + 1e-4

    def test_engine_rejects_budget_above_ceiling(self):
        with pytest.raises(ValueError, match="ceiling"):
            _engine("adaptive", g_total=2.0, num_gpus=1.0)


def test_allocation_capacity_every_tick():
    eng = _engine("adaptive")
    rng = np.random.default_rng(1)
    for _ in range(6):
        eng.submit("fast", rng.integers(0, 50, 4), 2)
        eng.step()
    for h in eng.history:
        assert sum(h["allocation"]) <= 1.0 + 1e-4


def test_requests_preserve_order_within_agent():
    eng = _engine("adaptive")
    rng = np.random.default_rng(2)
    reqs = [eng.submit("fast", rng.integers(0, 50, 4), 2) for _ in range(4)]
    for _ in range(12):
        eng.step()
    done = [r for r in eng.completed if r.agent == "fast"]
    ids = [r.id for r in done]
    assert ids == sorted(ids)


def test_generated_tokens_deterministic():
    a, b = _engine(), _engine()
    rng1, rng2 = np.random.default_rng(5), np.random.default_rng(5)
    for eng, rng in ((a, rng1), (b, rng2)):
        for _ in range(4):
            eng.submit("fast", rng.integers(0, 50, 5), 3)
            eng.step()
        for _ in range(4):
            eng.step()
    ta = [r.tokens_out for r in a.completed]
    tb = [r.tokens_out for r in b.completed]
    assert ta == tb
