"""Serving-engine integration: the paper's allocator driving real models."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import allocator as alloc
from repro.core.agents import AgentSpec, Fleet
from repro.models.model import build_model
from repro.serving.engine import AgentRuntime, FleetEngine


def _fleet_2():
    return Fleet.from_specs([
        AgentSpec("fast", 100.0, 100.0, 0.2, 1),
        AgentSpec("slow", 500.0, 20.0, 0.3, 2),
    ])


def _engine(policy="adaptive", budget_tokens=32, **kwargs):
    fleet = _fleet_2()
    key = jax.random.key(0)
    rts = {}
    for name, arch in (("fast", "minitron-4b"), ("slow", "mamba2-370m")):
        cfg = get_config(arch, reduced=True)
        api = build_model(cfg)
        rts[name] = AgentRuntime(name, api, api.init(key), max_len=48, batch_slots=2)
    return FleetEngine(fleet, rts, policy=policy, budget_tokens=budget_tokens, **kwargs)


@pytest.mark.parametrize("policy", ["adaptive", "static_equal", "round_robin",
                                    "water_filling", "predictive"])
def test_engine_completes_requests(policy):
    eng = _engine(policy)
    rng = np.random.default_rng(0)
    for t in range(10):
        eng.submit("fast", rng.integers(0, 50, 6), max_new_tokens=3)
        if t % 2 == 0:
            eng.submit("slow", rng.integers(0, 50, 6), max_new_tokens=3)
        eng.step()
    m = eng.metrics()
    assert m["completed"] > 0
    assert m["tokens_generated"] >= m["completed"] * 3


def test_every_registered_policy_dispatches_in_engine():
    """Regression: every POLICY_NAMES entry (incl. throughput_greedy, which
    used to raise ValueError here) must run end-to-end through the engine."""
    eng = _engine()
    rng = np.random.default_rng(3)
    for policy in alloc.policy_names():
        eng.policy = policy
        eng.submit("fast", rng.integers(0, 50, 4), 2)
        eng.step()
    assert eng.tick == len(alloc.policy_names())
    for h in eng.history:
        assert sum(h["allocation"]) <= 1.0 + 1e-4
        assert min(h["allocation"]) >= -1e-6


def test_engine_rejects_unknown_policy():
    with pytest.raises(ValueError, match="registered policies"):
        _engine("not_a_policy")


def test_engine_ema_uses_configured_alpha():
    eng = _engine("predictive", budget_tokens=16, ema_alpha=0.5)
    eng.submit("fast", np.arange(4), 1)
    eng.step()
    # zeros seed + one update: ema = alpha * lam
    np.testing.assert_allclose(eng._ema, [0.5, 0.0], atol=1e-6)
    eng.step()
    np.testing.assert_allclose(eng._ema, [0.25, 0.0], atol=1e-6)


def test_allocation_capacity_every_tick():
    eng = _engine("adaptive")
    rng = np.random.default_rng(1)
    for _ in range(6):
        eng.submit("fast", rng.integers(0, 50, 4), 2)
        eng.step()
    for h in eng.history:
        assert sum(h["allocation"]) <= 1.0 + 1e-4


def test_requests_preserve_order_within_agent():
    eng = _engine("adaptive")
    rng = np.random.default_rng(2)
    reqs = [eng.submit("fast", rng.integers(0, 50, 4), 2) for _ in range(4)]
    for _ in range(12):
        eng.step()
    done = [r for r in eng.completed if r.agent == "fast"]
    ids = [r.id for r in done]
    assert ids == sorted(ids)


def test_generated_tokens_deterministic():
    a, b = _engine(), _engine()
    rng1, rng2 = np.random.default_rng(5), np.random.default_rng(5)
    for eng, rng in ((a, rng1), (b, rng2)):
        for _ in range(4):
            eng.submit("fast", rng.integers(0, 50, 5), 3)
            eng.step()
        for _ in range(4):
            eng.step()
    ta = [r.tokens_out for r in a.completed]
    tb = [r.tokens_out for r in b.completed]
    assert ta == tb
