"""Unit + property tests for the allocation policies (paper Algorithm 1)."""
import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import allocator as alloc
from repro.core.agents import paper_fleet, PAPER_ARRIVAL_RATES

fleet = paper_fleet()
LAM = jnp.asarray(PAPER_ARRIVAL_RATES, jnp.float32)


class TestAdaptive:
    def test_paper_allocation_exact(self):
        """Algorithm 1 on Table I inputs -> the allocation behind Table II."""
        g = alloc.adaptive_allocation(LAM, fleet.min_gpu, fleet.priority)
        np.testing.assert_allclose(
            np.asarray(g), [0.23865, 0.25380, 0.21150, 0.29605], atol=2e-4
        )
        # Σ g_i·T_i = 58.1 rps — the paper's adaptive throughput.
        assert abs(float((g * fleet.base_throughput).sum()) - 58.1) < 0.05

    def test_zero_demand_releases_everything(self):
        g = alloc.adaptive_allocation(jnp.zeros(4), fleet.min_gpu, fleet.priority)
        assert float(jnp.abs(g).sum()) == 0.0

    def test_minimums_respected_when_capacity_allows(self):
        lam = jnp.asarray([1.0, 1.0, 1.0], jnp.float32)
        mins = jnp.asarray([0.1, 0.2, 0.3], jnp.float32)
        pri = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
        g = alloc.adaptive_allocation(lam, mins, pri)
        assert bool((g >= mins - 1e-6).all())

    def test_priority_weighting(self):
        """Same load/min, higher priority (lower P) -> no smaller share."""
        lam = jnp.asarray([10.0, 10.0], jnp.float32)
        mins = jnp.asarray([0.1, 0.1], jnp.float32)
        g = alloc.adaptive_allocation(lam, mins, jnp.asarray([1.0, 3.0]))
        assert float(g[0]) > float(g[1])

    @hypothesis.given(
        lam=st.lists(st.floats(0, 1e4), min_size=1, max_size=16),
        mins=st.lists(st.floats(0, 1.0), min_size=1, max_size=16),
        pri=st.lists(st.integers(1, 3), min_size=1, max_size=16),
        g_total=st.floats(0.1, 4.0),
    )
    @hypothesis.settings(max_examples=200, deadline=None)
    def test_capacity_invariant(self, lam, mins, pri, g_total):
        n = min(len(lam), len(mins), len(pri))
        g = alloc.adaptive_allocation(
            jnp.asarray(lam[:n], jnp.float32),
            jnp.asarray(mins[:n], jnp.float32),
            jnp.asarray(pri[:n], jnp.float32),
            g_total,
        )
        arr = np.asarray(g)
        assert (arr >= -1e-6).all()
        assert arr.sum() <= g_total * (1 + 1e-4)
        assert not np.isnan(arr).any()

    @hypothesis.given(scale=st.floats(0.01, 100.0))
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_scale_invariance_in_arrivals(self, scale):
        """d_i ∝ λ_i, so uniform λ scaling leaves the allocation unchanged."""
        g1 = alloc.adaptive_allocation(LAM, fleet.min_gpu, fleet.priority)
        g2 = alloc.adaptive_allocation(LAM * scale, fleet.min_gpu, fleet.priority)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


class TestBaselines:
    def test_static_equal(self):
        g = alloc.static_equal(4)
        np.testing.assert_allclose(np.asarray(g), 0.25)

    @pytest.mark.parametrize("t", [0, 1, 5, 103])
    def test_round_robin_one_hot(self, t):
        g = np.asarray(alloc.round_robin(jnp.asarray(t), 4))
        assert g.sum() == 1.0
        assert (g > 0).sum() == 1
        assert g[t % 4] == 1.0


class TestBeyondPaper:
    @hypothesis.given(
        q=st.lists(st.floats(0, 1e4), min_size=2, max_size=4),
        lam=st.lists(st.floats(0, 1e3), min_size=2, max_size=4),
    )
    @hypothesis.settings(max_examples=100, deadline=None)
    def test_water_filling_capacity(self, q, lam):
        n = min(len(q), len(lam), fleet.num_agents)
        g = alloc.water_filling(
            jnp.asarray(q[:n], jnp.float32),
            jnp.asarray(lam[:n], jnp.float32),
            fleet.base_throughput[:n],
            fleet.min_gpu[:n],
        )
        arr = np.asarray(g)
        assert arr.sum() <= 1 + 1e-4 and (arr >= -1e-6).all()

    def test_water_filling_equalizes_latency(self):
        """Without binding minimums, q/(gT) should be equal across agents."""
        q = jnp.asarray([100.0, 200.0, 400.0], jnp.float32)
        T = jnp.asarray([10.0, 20.0, 40.0], jnp.float32)
        g = alloc.water_filling(q, jnp.zeros(3), T, jnp.zeros(3))
        lat = np.asarray(q / (g * T))
        assert lat.std() / lat.mean() < 1e-4

    def test_throughput_greedy_beats_adaptive_on_served(self):
        """With loose minimums, greedy should serve >= adaptive's capacity."""
        q = jnp.asarray([1000.0, 1000.0, 1000.0, 1000.0], jnp.float32)
        mins = jnp.zeros(4)
        g_greedy = alloc.throughput_greedy(q, LAM, fleet.base_throughput, mins)
        g_adapt = alloc.adaptive_allocation(LAM, fleet.min_gpu, fleet.priority)
        served_g = float((g_greedy * fleet.base_throughput).sum())
        served_a = float((g_adapt * fleet.base_throughput).sum())
        assert served_g >= served_a - 1e-3

    def test_predictive_matches_adaptive_on_steady_state(self):
        g1 = alloc.adaptive_allocation(LAM, fleet.min_gpu, fleet.priority)
        g2 = alloc.predictive_adaptive(LAM, fleet.min_gpu, fleet.priority)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2))

    @hypothesis.given(
        q=st.lists(st.floats(0, 1e4), min_size=4, max_size=4),
        lam=st.lists(st.floats(0, 500), min_size=4, max_size=4),
    )
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_objective_descent_capacity_and_mins(self, q, lam):
        g = alloc.objective_descent(
            jnp.asarray(q, jnp.float32), jnp.asarray(lam, jnp.float32),
            fleet.base_throughput, fleet.min_gpu, fleet.priority,
        )
        arr = np.asarray(g)
        assert not np.isnan(arr).any()
        assert arr.sum() <= 1 + 1e-4 and (arr >= -1e-6).all()

    def test_objective_descent_no_worse_than_adaptive_on_eq2(self):
        """The descent policy optimizes Eq.(2); it must score <= Algorithm 1."""
        from repro.core.objective import step_objective

        q = jnp.asarray([500.0, 300.0, 200.0, 100.0], jnp.float32)
        g_a = alloc.adaptive_allocation(LAM, fleet.min_gpu, fleet.priority)
        g_o = alloc.objective_descent(q, LAM, fleet.base_throughput,
                                      fleet.min_gpu, fleet.priority, gamma=1.0)
        ja = step_objective(g_a, q, LAM, fleet.base_throughput)
        jo = step_objective(g_o, q, LAM, fleet.base_throughput)
        assert float(jo) <= float(ja) + 1e-3
