"""MoE layer invariants: grouped == einsum dispatch, capacity drops,
router load-balance loss."""
import dataclasses

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe
from repro.models.config import ModelConfig
from repro.models.params import init_params


def _cfg(e, k, d=64, f=32):
    return ModelConfig(
        name="t", arch_type="moe", num_layers=1, d_model=d, vocab_size=128,
        num_heads=2, num_kv_heads=1, d_ff=f, num_experts=e, experts_per_token=k,
    )


def _setup(cfg, seed=0):
    p = init_params(moe.moe_decls(cfg), jax.random.key(seed), dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(seed + 1), (2, 16, cfg.d_model), jnp.float32) * 0.5
    return p, x


@hypothesis.given(
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
    seed=st.integers(0, 5),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_grouped_equals_einsum(e, k, seed):
    cfg = _cfg(e, min(k, e))
    p, x = _setup(cfg, seed)
    y1, a1 = moe.moe_ffn(x, p, cfg)
    y2, a2 = moe.moe_ffn_grouped(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_capacity_drop_consistency():
    """With a tiny capacity factor both impls drop the SAME tokens."""
    cfg = _cfg(4, 2)
    p, x = _setup(cfg)
    y1, _ = moe.moe_ffn(x, p, cfg, capacity_factor=0.25)
    y2, _ = moe.moe_ffn_grouped(x, p, cfg, capacity_factor=0.25)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    # and dropping must change the output vs full capacity
    yfull, _ = moe.moe_ffn(x, p, cfg, capacity_factor=4.0)
    assert float(jnp.abs(yfull - y1).max()) > 1e-6


def test_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing gives aux = E * E * (1/E) * (1/E) * E = 1."""
    cfg = _cfg(8, 1)
    p, x = _setup(cfg)
    # zero router weights -> uniform probs; top-1 picks expert 0 every time
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    _, ids, aux = moe._router(x, p, cfg)
    # f_0 = 1, p_e = 1/E -> aux = E * (1 * 1/E) = 1
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-5)


def test_moe_gradients_flow_to_all_used_experts():
    cfg = _cfg(4, 2)
    p, x = _setup(cfg)

    def loss(p):
        y, aux = moe.moe_ffn(x, p, cfg)
        return (y ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    gnorm = float(jnp.abs(g["router"]).sum())
    assert gnorm > 0  # router receives gradient through combine weights
    assert float(jnp.abs(g["w_gate"]).sum()) > 0


def test_full_configs_route_correct_topk():
    for arch in ("mixtral-8x7b", "granite-moe-1b-a400m"):
        cfg = get_config(arch, reduced=True)
        p = init_params(moe.moe_decls(cfg), jax.random.key(0), dtype=jnp.float32)
        x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model), jnp.float32)
        w, ids, _ = moe._router(x, p, cfg)
        assert ids.shape == (1, 8, cfg.experts_per_token)
        assert int(ids.max()) < cfg.num_experts
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
