"""In-scan workload synthesis — registry semantics + the parity contract.

The streaming kernel can synthesize step t's arrival row *inside* the scan
from an O(N) ``WorkloadSpec`` instead of indexing a materialized (S, N)
tensor.  The acceptance contract is **bit-for-bit equality** between the
two arms — not a tolerance — because ``workload.materialize`` scans the
very same registered step functions the in-scan arm runs.  Three parity
layers here:

* **Generator layer** — ``materialize(spec)`` against
  ``reference_sim.synthesize_loop`` (an eager python loop threading the
  generator state by hand), exact, for every library spec and
  hypothesis-driven over (generator × key × horizon) including the MMPP
  carry of ``bursty``/``correlated``.
* **Kernel layer** — ``simulate_stream_core`` with ``workload_spec=`` vs
  the same spec materialized to an arrivals tensor, exact, including the
  FMA-sensitive ``predictive`` policy (see ``allocator._committed``).
* **Entry-point layer** — all four sweep entry points with
  ``synthesize=True`` vs ``synthesize=False``, exact; plus the
  ``REPRO_SWEEP_SYNTH=0`` escape hatch, the tensor+synthesize=True
  rejection, and key-reproducibility of ``synthetic_rates``.

The float64 numpy oracle closes the loop: a synthesized workload pushed
through ``simulate`` matches ``simulate_numpy`` on the eager-loop tensor.
"""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import workload
from repro.core.agents import synthetic_fleet
from repro.core.reference_sim import simulate_numpy, synthesize_loop
from repro.core.simulator import SimConfig, simulate, simulate_stream_core
from repro.core.sweep import (
    scenario_library,
    sweep,
    sweep_capacity,
    sweep_fleets,
    sweep_workflows,
)
from repro.core.workload import synthetic_rates

NUM_STEPS = 12
RATES = synthetic_rates(4, seed=0)
FLEET = synthetic_fleet(4, seed=0)
# predictive is deliberately included: its EMA update is the one place the
# synthesized and materialized executables used to diverge by 1 ulp (FMA
# contraction; pinned by allocator._committed).
POLICIES = ("static_equal", "adaptive", "predictive")


def _spec_for(gen: str, rates, steps: int, key) -> workload.WorkloadSpec:
    """One library spec per registered generator name."""
    if gen == "constant":
        return workload.constant_spec(rates, steps)
    if gen == "poisson":
        return workload.poisson_spec(rates, steps, key)
    if gen == "spike":
        return workload.spike_spec(
            rates, steps, spike_agent=1, spike_start=steps // 2,
            spike_len=max(steps // 4, 1),
        )
    if gen == "diurnal":
        return workload.diurnal_spec(rates, steps, period=5)
    if gen == "bursty":
        return workload.bursty_spec(rates, steps, key)
    if gen == "correlated":
        return workload.correlated_spec(rates, steps, key)
    raise AssertionError(gen)


# -- registry semantics ------------------------------------------------------


def test_registry_names_ids_round_trip():
    names = workload.workload_names()
    assert set(names) == {
        "constant", "poisson", "spike", "diurnal", "bursty", "correlated"
    }
    for i, name in enumerate(names):
        assert workload.workload_id(name) == i
    with pytest.raises(ValueError):
        workload.workload_id("nope")


def test_register_rejects_duplicate_name():
    with pytest.raises(ValueError, match="already registered"):
        workload.register_workload("constant")(lambda *a: a)


def test_scenario_specs_mirror_library_names():
    specs = workload.scenario_specs(RATES, num_steps=NUM_STEPS)
    library = scenario_library(RATES, num_steps=NUM_STEPS)
    assert tuple(s.name for s in specs) == tuple(s.name for s in library)


def test_synthetic_rates_key_reproducible():
    np.testing.assert_array_equal(
        synthetic_rates(6, seed=3), synthetic_rates(6, seed=3)
    )
    assert not np.array_equal(
        synthetic_rates(6, seed=3), synthetic_rates(6, seed=4)
    )


# -- generator layer: materialize vs the eager python loop -------------------


def test_materialize_matches_eager_loop_all_library_specs():
    for spec in workload.scenario_specs(RATES, num_steps=NUM_STEPS):
        np.testing.assert_array_equal(
            np.asarray(workload.materialize(spec), np.float64),
            synthesize_loop(spec),
            err_msg=spec.name,
        )


def test_mmpp_carry_parity_long_horizon():
    """bursty/correlated thread MMPP state through the scan carry; a longer
    horizon catches any drift in how the state is re-threaded."""
    for gen in ("bursty", "correlated"):
        spec = _spec_for(gen, RATES, 60, jax.random.key(7))
        np.testing.assert_array_equal(
            np.asarray(workload.materialize(spec), np.float64),
            synthesize_loop(spec),
            err_msg=gen,
        )


@hypothesis.given(
    gen=st.sampled_from(
        ("constant", "poisson", "spike", "diurnal", "bursty", "correlated")
    ),
    seed=st.integers(0, 2**31 - 1),
    steps=st.sampled_from((1, 3, 7, 20)),
)
@hypothesis.settings(max_examples=40, deadline=None)
def test_generator_parity_property(gen, seed, steps):
    """Every generator × key × horizon: the scan and the eager loop agree
    bit-for-bit (the counter-based fold_in draw has no sequential state to
    desynchronize)."""
    spec = _spec_for(gen, RATES, steps, jax.random.key(seed))
    np.testing.assert_array_equal(
        np.asarray(workload.materialize(spec), np.float64),
        synthesize_loop(spec),
    )


@hypothesis.given(
    gen=st.sampled_from(
        ("constant", "poisson", "spike", "diurnal", "bursty", "correlated")
    ),
    seed=st.integers(0, 2**31 - 1),
    # 7-step blocks over 20 steps leave a ragged 6-step tail; block 25 > S
    # covers the single-short-block path.
    steps=st.sampled_from((1, 3, 20)),
    block=st.sampled_from((2, 7, 25)),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_step_block_parity_property(gen, seed, steps, block):
    """``workload.step_block`` (the blocked vectorized synthesis the
    time-blocked kernel runs, here driven by ``synthesize_loop``'s
    ``block_size`` walk) produces the same draws bit-for-bit as the
    per-step path, with MMPP state threaded across block boundaries and
    ragged tails handled eagerly."""
    spec = _spec_for(gen, RATES, steps, jax.random.key(seed))
    np.testing.assert_array_equal(
        synthesize_loop(spec, block_size=block),
        synthesize_loop(spec),
    )


# -- kernel layer: in-scan synthesis vs materialized arrivals ----------------


def _stream_pair(spec, **kwargs):
    config = SimConfig()
    mat = simulate_stream_core(
        workload.materialize(spec), FLEET, config, POLICIES, **kwargs
    )
    synth = simulate_stream_core(
        None, FLEET, config, POLICIES, workload_spec=spec, **kwargs
    )
    return mat, synth


def test_stream_core_synth_bit_identical_all_library_specs():
    for spec in workload.scenario_specs(RATES, num_steps=NUM_STEPS):
        mat, synth = _stream_pair(spec)
        for m, s in zip(mat, synth):
            np.testing.assert_array_equal(
                np.asarray(m), np.asarray(s), err_msg=spec.name
            )


@hypothesis.given(
    gen=st.sampled_from(
        ("constant", "poisson", "spike", "diurnal", "bursty", "correlated")
    ),
    seed=st.integers(0, 2**31 - 1),
    steps=st.sampled_from((5, 13)),
)
@hypothesis.settings(max_examples=18, deadline=None)
def test_stream_core_parity_property(gen, seed, steps):
    """The full kernel contract: every workload type × key × horizon, the
    in-scan arm equals the materialized arm exactly — MMPP carry, EMA
    seeding, and the predictive policy's FMA-pinned update included."""
    spec = _spec_for(gen, RATES, steps, jax.random.key(seed))
    mat, synth = _stream_pair(spec)
    for m, s in zip(mat, synth):
        np.testing.assert_array_equal(np.asarray(m), np.asarray(s))


def test_stream_core_requires_exactly_one_input_side():
    arr = workload.materialize(workload.constant_spec(RATES, NUM_STEPS))
    spec = workload.constant_spec(RATES, NUM_STEPS)
    with pytest.raises(ValueError, match="exactly one"):
        simulate_stream_core(arr, FLEET, SimConfig(), POLICIES,
                             workload_spec=spec)
    with pytest.raises(ValueError, match="exactly one"):
        simulate_stream_core(None, FLEET, SimConfig(), POLICIES)


# -- entry-point layer -------------------------------------------------------


def _entry_grids(synthesize):
    """All four entry points on the SAME spec scenarios.

    ``scenarios=`` is passed explicitly where the entry point would
    otherwise default to the legacy tensor library for ``synthesize=False``
    (legitimately different stochastic draws — the parity contract is
    between the two *arms over the same specs*, not specs vs legacy)."""
    specs = workload.scenario_specs(RATES, num_steps=NUM_STEPS)
    fleets = [synthetic_fleet(n, seed=i) for i, n in enumerate((2, 3, 4))]
    return {
        "sweep": sweep(FLEET, specs, policies=POLICIES,
                       synthesize=synthesize).metrics,
        # sweep_fleets builds matched per-fleet specs for any non-None
        # synthesize; False is its documented materialized parity arm.
        "fleets": sweep_fleets(fleets, num_steps=NUM_STEPS, seed=0,
                               policies=POLICIES,
                               synthesize=synthesize).metrics,
        "workflows": sweep_workflows(FLEET, scenarios=specs,
                                     num_steps=NUM_STEPS, policies=POLICIES,
                                     synthesize=synthesize).metrics,
        "capacity": sweep_capacity(FLEET, scenarios=specs,
                                   num_steps=NUM_STEPS, policies=POLICIES,
                                   synthesize=synthesize).metrics,
    }


def test_all_entry_points_synth_bit_identical_to_materialized():
    synth, mat = _entry_grids(True), _entry_grids(False)
    for name in synth:
        np.testing.assert_array_equal(synth[name], mat[name], err_msg=name)


def test_synth_env_hatch_forces_materialized_path(monkeypatch):
    reference = _entry_grids(True)
    monkeypatch.setenv(workload.SYNTH_ENV, "0")
    assert not workload.synth_env_enabled()
    hatch = _entry_grids(True)  # synthesize=True, but the hatch wins
    for name in hatch:
        np.testing.assert_array_equal(hatch[name], reference[name],
                                      err_msg=name)


def test_tensor_scenarios_reject_synthesize():
    tensors = scenario_library(RATES, num_steps=NUM_STEPS)
    with pytest.raises(ValueError, match="WorkloadSpec"):
        sweep(FLEET, tensors, policies=POLICIES, synthesize=True)
    specs = workload.scenario_specs(RATES, num_steps=NUM_STEPS)
    with pytest.raises(ValueError, match="not a mix"):
        sweep(FLEET, [tensors[0], specs[0]], policies=POLICIES)


# -- oracle closure ----------------------------------------------------------


def test_synthesized_workload_matches_numpy_oracle():
    """Synthesis feeding the float64 oracle: ``simulate`` on the
    materialized spec vs ``simulate_numpy`` on the eager-loop tensor —
    the two independent control-flow paths meet within float tolerance."""
    spec = workload.bursty_spec(RATES, 40, jax.random.key(11))
    arrivals = synthesize_loop(spec)
    for policy in ("adaptive", "predictive", "water_filling"):
        tr = simulate(policy, jnp.asarray(arrivals, jnp.float32), FLEET)
        ref = simulate_numpy(policy, arrivals, FLEET)
        for field in ("queue", "served", "latency"):
            np.testing.assert_allclose(
                np.asarray(getattr(tr, field), np.float64), ref[field],
                rtol=2e-4, atol=2e-3, err_msg=f"{policy}/{field}",
            )
