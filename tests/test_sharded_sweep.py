"""Device-sharded ``sweep_fleets`` coverage — trace AND streaming kernels.

ROADMAP flagged the sharded fleet axis (1D mesh + NamedSharding in
``core/sweep.py``) as never exercised on more than one device.  Two
complementary tests close that gap, each parametrized over both grid
kernels so the sharded and streaming paths are exercised together:

* **in-process** — runs when the interpreter already sees >= 2 devices
  (the dedicated CI step sets ``XLA_FLAGS=--xla_force_host_platform_
  device_count=8``); asserts the sharded grid equals the unsharded grid on
  the same devices, with the fleet count chosen divisible by the device
  count so the real ``PartitionSpec("grid")`` layout runs, not the
  replication fallback.  A cross-kernel check also pins the sharded
  streaming grid to the sharded trace grid within float tolerance.
* **subprocess** — always runnable: spawns a fresh interpreter with 8
  forced host CPU devices and compares its sharded metrics (both kernels)
  against this process's single-device references.  Skipped when the
  in-process variant already covers the path (>= 2 devices), so CI pays
  for each variant once.
"""
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

from repro.core.agents import synthetic_fleet
from repro.core.sweep import sweep_fleets

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

# Small but heterogeneous: 8 fleets so an 8-device mesh shards 1:1.
FLEET_SIZES = (2, 3, 4, 5, 2, 3, 4, 5)
NUM_STEPS = 12
POLICIES = ("static_equal", "adaptive", "water_filling")


def _grid(shard: bool, stream: bool) -> np.ndarray:
    fleets = [synthetic_fleet(n, seed=i) for i, n in enumerate(FLEET_SIZES)]
    res = sweep_fleets(
        fleets, num_steps=NUM_STEPS, seed=0, policies=POLICIES, shard=shard,
        stream=stream,
    )
    return res.metrics


@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(covered by the subprocess variant on single-device runs)",
)
@pytest.mark.parametrize("stream", (False, True), ids=("trace", "streaming"))
def test_sharded_matches_unsharded_in_process(stream):
    assert len(FLEET_SIZES) % jax.device_count() == 0, (
        "fleet count must divide the device count to exercise the real "
        "sharded layout instead of the replication fallback"
    )
    np.testing.assert_allclose(
        _grid(shard=True, stream=stream), _grid(shard=False, stream=stream),
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(covered by the subprocess variant on single-device runs)",
)
def test_sharded_streaming_matches_sharded_trace_in_process():
    """The two kernels must agree on the device-sharded grid too — the
    streaming default cannot silently drift once a mesh is involved."""
    np.testing.assert_allclose(
        _grid(shard=True, stream=True), _grid(shard=True, stream=False),
        rtol=1e-3, atol=1e-3,
    )


_CHILD = """
import numpy as np
from repro.core.agents import synthetic_fleet
from repro.core.sweep import sweep_fleets
import jax
assert jax.device_count() == 8, jax.devices()
fleets = [synthetic_fleet(n, seed=i) for i, n in enumerate({sizes})]
for stream, out in ((False, {out_trace!r}), (True, {out_stream!r})):
    res = sweep_fleets(fleets, num_steps={steps}, seed=0, policies={policies},
                       shard=True, stream=stream)
    np.save(out, res.metrics)
"""


@pytest.mark.skipif(
    jax.device_count() >= 2,
    reason="in-process variant already exercises the multi-device path",
)
def test_sharded_8_device_subprocess_matches_single_device():
    # Single device: identity placement — the sharded path is a no-op.
    references = {
        False: _grid(shard=True, stream=False),
        True: _grid(shard=True, stream=True),
    }
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    with tempfile.TemporaryDirectory() as tmp:
        out_trace = os.path.join(tmp, "metrics_trace.npy")
        out_stream = os.path.join(tmp, "metrics_stream.npy")
        script = _CHILD.format(
            sizes=FLEET_SIZES, steps=NUM_STEPS, policies=POLICIES,
            out_trace=out_trace, out_stream=out_stream,
        )
        proc = subprocess.run(
            [sys.executable, "-c", script], env=env, capture_output=True,
            text=True, timeout=600,
        )
        assert proc.returncode == 0, (proc.stdout, proc.stderr)
        sharded = {False: np.load(out_trace), True: np.load(out_stream)}
    for stream, reference in references.items():
        np.testing.assert_allclose(
            sharded[stream], reference, rtol=1e-5, atol=1e-6,
            err_msg=f"stream={stream}",
        )
