"""Property tests for the policy-registry invariants (hypothesis-optional).

Every registered policy, under randomized (lam, queue) and padded/masked
fleets, must return

* g >= 0 everywhere,
* Σ g <= g_total,
* exactly g = 0 on padded (masked-out) slots, and
* the min-GPU floor for busy agents — unless capacity is saturated, in
  which case Algorithm 1's proportional scale-down (lines 21-25) is allowed
  to compress floors; baselines that ignore floors by design
  (static_equal / round_robin) are exempt.

The hypothesis-driven test skips cleanly when hypothesis is not installed
(tests/conftest.py stubs it); the deterministic sweep below covers the same
invariants with a fixed RNG either way.
"""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import allocator as alloc
from repro.core import workload
from repro.core.agents import pad_fleet, synthetic_fleet
from repro.core.capacity import capacity_config
from repro.core.simulator import SimConfig, simulate

# Policies that honor per-agent minimum guarantees; which agents count as
# "busy" depends on the demand signal each policy actually reads.
FLOOR_POLICIES = {
    "adaptive": lambda lam, q: lam > 0,
    "predictive": lambda lam, q: lam > 0,
    "water_filling": lambda lam, q: (lam + q) > 0,
    "throughput_greedy": lambda lam, q: (lam + q) > 0,
    "objective_descent": lambda lam, q: (lam + q) > 0,
    "sqrt_demand": lambda lam, q: (lam + q) > 0,
    # _check_invariants dispatches with lam_ema = lam, so the EMA-driven
    # pressure reduces to the water_filling predicate here.
    "ema_water_filling": lambda lam, q: (lam + q) > 0,
}


def _check_invariants(policy, fleet, lam, q, g_total, n_real):
    g = np.asarray(
        alloc.dispatch(policy, jnp.asarray(0), lam, lam, q, fleet, g_total)
    )
    assert not np.isnan(g).any(), policy
    assert (g >= -1e-6).all(), (policy, g.min())
    assert g.sum() <= g_total * (1 + 1e-4), (policy, g.sum())
    assert (g[n_real:] == 0.0).all(), (policy, g[n_real:])
    if policy in FLOOR_POLICIES:
        busy = np.asarray(FLOOR_POLICIES[policy](np.asarray(lam), np.asarray(q)))
        busy &= np.asarray(fleet.active) > 0
        floor = np.asarray(fleet.min_gpu)
        below = busy & (g < floor - 1e-5)
        if below.any():
            # Floors may only be compressed by the capacity normalization,
            # i.e. when the whole budget is spent.
            assert g.sum() >= g_total * (1 - 1e-3), (policy, g.sum(), g_total)


def _run_case(n_real, n_pad, seed, g_total, lam_vals, q_vals):
    fleet = pad_fleet(synthetic_fleet(n_real, seed=seed), n_real + n_pad)
    lam = jnp.zeros(n_real + n_pad, jnp.float32).at[:n_real].set(
        jnp.asarray(lam_vals[:n_real], jnp.float32)
    )
    q = jnp.zeros(n_real + n_pad, jnp.float32).at[:n_real].set(
        jnp.asarray(q_vals[:n_real], jnp.float32)
    )
    for policy in alloc.policy_names():
        _check_invariants(policy, fleet, lam, q, g_total, n_real)


@hypothesis.given(
    lam=st.lists(st.floats(0.0, 1e3), min_size=2, max_size=10),
    queue=st.lists(st.floats(0.0, 1e4), min_size=2, max_size=10),
    n_pad=st.integers(0, 6),
    g_total=st.floats(0.5, 2.0),
    seed=st.integers(0, 3),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_policy_invariants_property(lam, queue, n_pad, g_total, seed):
    n_real = min(len(lam), len(queue))
    _run_case(n_real, n_pad, seed, g_total, lam, queue)


@pytest.mark.parametrize("n_real,n_pad", [(3, 0), (4, 4), (7, 9)])
def test_policy_invariants_deterministic(n_real, n_pad):
    """Hypothesis-free coverage of the same invariants, fixed RNG."""
    rng = np.random.default_rng(n_real * 31 + n_pad)
    for case in range(5):
        lam = rng.uniform(0.0, 500.0, n_real)
        q = rng.uniform(0.0, 2000.0, n_real)
        if case == 3:
            lam[:] = 0.0  # idle fleet: everything must be released or floored
        if case == 4:
            q[:] = 0.0
        _run_case(n_real, n_pad, seed=case, g_total=1.0, lam_vals=lam, q_vals=q)


@hypothesis.given(
    n=st.integers(2, 8),
    seed=st.integers(0, 5),
    cap_policy=st.sampled_from(("reactive", "scale_to_zero")),
    cold=st.integers(0, 6),
    target_rate=st.floats(20.0, 120.0),
    keep_alive=st.floats(0.0, 8.0),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_budget_feasible_under_time_varying_traced_budget(
    n, seed, cap_policy, cold, target_rate, keep_alive
):
    """Under the serverless capacity layer the budget is a traced
    trajectory g_total(t) = warm(t) — including exact zeros when the pool
    sleeps.  Every registered policy must still emit Σg(t) <= g_total(t)
    and g >= 0 at every step, not just under the constant budget the
    original invariants were written against.  (Deterministic coverage of
    the same invariant: tests/test_capacity.py.)
    """
    fleet = synthetic_fleet(n, seed=seed)
    rates = workload.synthetic_rates(n, seed=seed)
    arr = workload.bursty(rates, 30, jax.random.key(seed))
    cap = capacity_config(
        cap_policy, cold_start_s=float(cold),
        target_rate_per_instance=target_rate, keep_alive_s=keep_alive,
    )
    config = SimConfig(g_total=1.0, num_gpus=6.0)
    for policy in alloc.policy_names():
        tr = simulate(policy, arr, fleet, config, capacity=cap)
        g = np.asarray(tr.allocation)
        warm = np.asarray(tr.warm)
        assert not np.isnan(g).any(), policy
        assert (g >= -1e-6).all(), (policy, g.min())
        assert (g.sum(axis=-1) <= warm * (1 + 1e-4) + 1e-6).all(), (
            policy, (g.sum(axis=-1) - warm).max(),
        )
