"""Spatial partition planner invariants (integer analogue of Algorithm 1)."""
import hypothesis
import hypothesis.strategies as st
import numpy as np

from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet
from repro.distributed.partition import PartitionPlan, plan_partition, should_repartition

fleet = paper_fleet()


def test_paper_fleet_on_256_chips():
    p = plan_partition(np.asarray(PAPER_ARRIVAL_RATES), np.asarray(fleet.min_gpu),
                       np.asarray(fleet.priority), 256)
    assert sum(p.chips) == 256
    # mirrors the fractional allocation (0.239/0.254/0.211/0.296)*256
    np.testing.assert_allclose(p.chips, [61, 65, 54, 76], atol=1)


@hypothesis.given(
    lam=st.lists(st.floats(0, 1e3), min_size=2, max_size=12),
    chips=st.sampled_from([8, 64, 256, 512]),
    seed=st.integers(0, 100),
)
@hypothesis.settings(max_examples=150, deadline=None)
def test_chips_conserved_and_busy_agents_nonzero(lam, chips, seed):
    n = len(lam)
    rng = np.random.default_rng(seed)
    mins = rng.uniform(0.01, 1.0 / n, n)
    pri = rng.integers(1, 4, n).astype(float)
    lam = np.asarray(lam)
    p = plan_partition(lam, mins, pri, chips)
    assert sum(p.chips) == (chips if lam.sum() > 0 else 0)
    if lam.sum() > 0 and chips >= n:
        for li, ci in zip(lam, p.chips):
            if li > 0:
                assert ci >= 1  # busy agents never starve


def test_idle_fleet_releases_chips():
    p = plan_partition(np.zeros(4), np.asarray(fleet.min_gpu),
                       np.asarray(fleet.priority), 256)
    assert sum(p.chips) == 0


def test_repartition_hysteresis():
    t = np.asarray([100.0, 30.0])
    cur = PartitionPlan((128, 128), (0.5, 0.5), 256)
    slightly = PartitionPlan((140, 116), (0.55, 0.45), 256)
    much = PartitionPlan((240, 16), (0.94, 0.06), 256)
    assert not should_repartition(cur, slightly, t)   # < 10% projected gain
    assert should_repartition(cur, much, t)
