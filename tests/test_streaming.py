"""Streaming sweep kernel: parity with the trace-based oracle.

The streaming kernel (``simulator.simulate_stream_core`` +
``sweep._stream_grid_jit``) replaces the vmapped ``lax.switch`` (P² policy
evaluations per grid under the evaluate-all-branches lowering) with an
unrolled per-policy stack, and accumulates the METRIC_NAMES reductions in
the scan carry instead of materializing (S, N) traces.  The trace-based
path is kept as the parity oracle; these tests pin the acceptance
criterion: streaming metrics match it within float tolerance for every
registered policy on all four grid types, including under a workflow
topology and an elastic capacity config.

Tolerances are float32 accumulation-order noise: the streaming carry sums
sequentially where the trace path tree-reduces, and ``latency_std``
amplifies the difference through cancellation at the ~1000 s latency cap.
"""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import importlib

from repro.core import allocator as alloc
from repro.core import routing
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet, synthetic_fleet
from repro.core.capacity import capacity_config
from repro.core.simulator import (
    METRIC_NAMES,
    SimConfig,
    resolve_block_size,
    simulate,
    simulate_stream_core,
    trace_metrics,
)
from repro.core.sweep import (
    scenario_library,
    sweep,
    sweep_capacity,
    sweep_fleets,
    sweep_workflows,
)

# The package re-exports the ``sweep`` *function* under the submodule's
# name, so reach the module itself through importlib.
sweep_mod = importlib.import_module("repro.core.sweep")

FLEET = paper_fleet()
RTOL, ATOL = 1e-3, 1e-3

ELASTIC = capacity_config(
    "reactive", cold_start_s=3.0, min_instances=1.0, name="reactive_cold"
)


def _assert_grids_match(streamed, traced, label):
    assert streamed.metrics.shape == traced.metrics.shape, label
    np.testing.assert_allclose(
        streamed.metrics, traced.metrics, rtol=RTOL, atol=ATOL, err_msg=label
    )
    np.testing.assert_allclose(
        streamed.per_agent_latency, traced.per_agent_latency,
        rtol=RTOL, atol=ATOL, err_msg=label,
    )
    np.testing.assert_allclose(
        streamed.per_agent_throughput, traced.per_agent_throughput,
        rtol=RTOL, atol=ATOL, err_msg=label,
    )
    np.testing.assert_allclose(
        streamed.per_agent_queue, traced.per_agent_queue,
        rtol=RTOL, atol=ATOL, err_msg=label,
    )


class TestStreamingIsDefault:
    def test_keep_traces_false_routes_to_streaming_kernel(self, monkeypatch):
        calls = []
        real = sweep_mod._stream_grid_jit

        def spy(*args, **kwargs):
            calls.append(1)
            return real(*args, **kwargs)

        monkeypatch.setattr(sweep_mod, "_stream_grid_jit", spy)
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=10, seed=0)
        sweep(FLEET, scen)
        assert calls, "keep_traces=False must default to the streaming kernel"

    def test_keep_traces_true_uses_trace_kernel(self, monkeypatch):
        monkeypatch.setattr(
            sweep_mod, "_stream_grid_jit",
            lambda *a, **k: pytest.fail("trace sweep hit the streaming kernel"),
        )
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=10, seed=0)
        res = sweep(FLEET, scen[:1], keep_traces=True)
        assert res.traces is not None

    def test_stream_with_keep_traces_rejected(self):
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=10, seed=0)
        with pytest.raises(ValueError, match="streaming"):
            sweep(FLEET, scen, keep_traces=True, stream=True)


class TestGridParity:
    """Acceptance: streaming matches the trace oracle on all four grids."""

    def test_sweep(self):
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=40, seed=0)
        _assert_grids_match(
            sweep(FLEET, scen), sweep(FLEET, scen, stream=False), "sweep"
        )

    def test_sweep_with_capacity(self):
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=40, seed=0)
        _assert_grids_match(
            sweep(FLEET, scen, capacity=ELASTIC),
            sweep(FLEET, scen, capacity=ELASTIC, stream=False),
            "sweep+capacity",
        )

    def test_sweep_fleets(self):
        fleets = [synthetic_fleet(n, seed=n) for n in (2, 3, 5)]
        _assert_grids_match(
            sweep_fleets(fleets, num_steps=25, seed=0),
            sweep_fleets(fleets, num_steps=25, seed=0, stream=False),
            "sweep_fleets",
        )

    def test_sweep_workflows(self):
        _assert_grids_match(
            sweep_workflows(FLEET, num_steps=25, seed=0),
            sweep_workflows(FLEET, num_steps=25, seed=0, stream=False),
            "sweep_workflows",
        )

    def test_sweep_capacity(self):
        _assert_grids_match(
            sweep_capacity(FLEET, num_steps=25, seed=0),
            sweep_capacity(FLEET, num_steps=25, seed=0, stream=False),
            "sweep_capacity",
        )

    def test_policy_subset(self):
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=20, seed=0)
        pols = ("water_filling", "round_robin")
        streamed = sweep(FLEET, scen, policies=pols)
        traced = sweep(FLEET, scen, policies=pols, stream=False)
        assert streamed.policy_names == pols
        _assert_grids_match(streamed, traced, "policy subset")


class TestStreamCoreAgainstSingleRuns:
    """Row i of the streaming stack must be policy names[i]'s own run —
    exactly one dispatch per registered policy, against ``simulate`` (the
    single-run ``lax.switch`` path, untouched by this kernel)."""

    ARR = workload.poisson(
        jnp.asarray(PAPER_ARRIVAL_RATES, jnp.float32), 50, jax.random.key(7)
    )

    @pytest.mark.parametrize(
        "workflow,capacity",
        [
            (None, None),
            (routing.coordinator_star(4), None),
            (None, ELASTIC),
            (routing.pipeline_chain(4), ELASTIC),
        ],
        ids=("plain", "workflow", "capacity", "workflow+capacity"),
    )
    def test_every_policy_row_matches_its_simulate(self, workflow, capacity):
        cfg = SimConfig()
        names = alloc.policy_names()
        vec, per_lat, per_tput, per_q = simulate_stream_core(
            self.ARR, FLEET, cfg, names, workflow, capacity
        )
        assert vec.shape == (len(names), len(METRIC_NAMES))
        for i, name in enumerate(names):
            tr = simulate(name, self.ARR, FLEET, cfg, workflow, capacity)
            want, want_lat, want_tput, want_q = trace_metrics(
                tr, FLEET.active, workflow, config=cfg
            )
            np.testing.assert_allclose(
                np.asarray(vec[i]), np.asarray(want),
                rtol=RTOL, atol=ATOL, err_msg=name,
            )
            np.testing.assert_allclose(
                np.asarray(per_lat[i]), np.asarray(want_lat),
                rtol=RTOL, atol=ATOL, err_msg=name,
            )
            np.testing.assert_allclose(
                np.asarray(per_tput[i]), np.asarray(want_tput),
                rtol=RTOL, atol=ATOL, err_msg=name,
            )
            np.testing.assert_allclose(
                np.asarray(per_q[i]), np.asarray(want_q),
                rtol=RTOL, atol=ATOL, err_msg=name,
            )


class TestTimeBlocking:
    """The time-blocked two-level scan is a pure schedule change:
    ``block_size`` must never alter a single bit of any output."""

    # Covers the two new registered policies alongside EMA-coupled ones;
    # a subset keeps per-shape XLA compiles affordable (the full-registry
    # bit-identity bar is held by the B=1 routing — identical scan — plus
    # the property below exercising blocked dispatch itself).
    NAMES = ("adaptive", "water_filling", "sqrt_demand", "ema_water_filling")

    def test_env_var_matches_explicit_block_size(self, monkeypatch):
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=13, seed=0)[:2]
        base = sweep(FLEET, scen, policies=self.NAMES)
        explicit = sweep(FLEET, scen, policies=self.NAMES, block_size=4)
        monkeypatch.setenv("REPRO_SWEEP_BLOCK", "4")
        via_env = sweep(FLEET, scen, policies=self.NAMES)
        np.testing.assert_array_equal(
            np.asarray(explicit.metrics), np.asarray(base.metrics)
        )
        np.testing.assert_array_equal(
            np.asarray(via_env.metrics), np.asarray(base.metrics)
        )

    def test_block_size_below_one_rejected(self):
        with pytest.raises(ValueError, match="block_size"):
            resolve_block_size(0)
        with pytest.raises(ValueError, match="block_size"):
            resolve_block_size(-3)

    @hypothesis.given(
        gen=st.sampled_from(("poisson", "bursty", "correlated", "diurnal")),
        key=st.integers(0, 6),
        # Both horizons are indivisible by 3 and 64, so every blocked run
        # exercises the masked tail; at 65 steps B=3 crosses 21 block
        # boundaries with the MMPP regime state carried across each one,
        # and B=64 covers full-block + tail; at 20 steps B=64 > S covers
        # the tail-only path.
        num_steps=st.sampled_from((20, 65)),
        synth=st.booleans(),
    )
    @hypothesis.settings(max_examples=10, deadline=None)
    def test_blocked_scan_is_bit_identical(self, gen, key, num_steps, synth):
        n = 4
        rates = workload.synthetic_rates(n, seed=1)
        fleet = synthetic_fleet(n, seed=1)
        if gen == "diurnal":
            spec = workload.diurnal_spec(rates, num_steps)
        else:
            spec = getattr(workload, f"{gen}_spec")(
                rates, num_steps, jax.random.key(key)
            )
        cfg = SimConfig()
        arr = None if synth else workload.materialize(spec)
        wspec = spec if synth else None
        base = simulate_stream_core(
            arr, fleet, cfg, self.NAMES, workload_spec=wspec, block_size=1
        )
        for b in (3, 64):
            got = simulate_stream_core(
                arr, fleet, cfg, self.NAMES, workload_spec=wspec, block_size=b
            )
            for part, want in zip(got, base):
                np.testing.assert_array_equal(
                    np.asarray(part), np.asarray(want),
                    err_msg=f"{gen}/key={key}/S={num_steps}/B={b}/synth={synth}",
                )

    def test_gen_grouped_dispatch_bit_identical(self):
        """The grouped static-dispatch synth path (``synth_gen_groups`` —
        one vmap per generator group, no vmapped switch) must reproduce the
        switch path bit-for-bit, across block sizes, on the full scenario
        library (every registered generator plus a multi-member constant
        group, in interleaved order)."""
        n = 4
        fleet = synthetic_fleet(n, seed=0)
        specs = workload.scenario_specs(
            workload.synthetic_rates(n, seed=0), num_steps=23, seed=0
        )
        stack = workload.stack_specs(specs)
        groups = sweep_mod.synth_gen_groups(stack)
        # The library interleaves generators, so grouping really permutes.
        assert groups is not None and len(groups) > 1
        assert sorted(i for _, idx in groups for i in idx) == list(
            range(len(specs))
        )
        cfg = SimConfig()
        for b in (1, 4):
            base = sweep_mod._stream_grid_jit(
                None, fleet, None, None, stack, None, cfg, self.NAMES, None,
                1, b
            )
            grouped = sweep_mod._stream_grid_jit(
                None, fleet, None, None, stack, None, cfg, self.NAMES, None,
                1, b, gen_groups=groups,
            )
            for part, want in zip(grouped, base):
                np.testing.assert_array_equal(
                    np.asarray(part), np.asarray(want), err_msg=f"B={b}"
                )


@hypothesis.given(
    n=st.integers(2, 4),
    seed=st.integers(0, 10),
    # Discrete horizons so examples share compiled scans instead of paying
    # one XLA compile per drawn shape.
    num_steps=st.sampled_from((12, 30)),
    topology=st.sampled_from(("none", "star", "chain", "synthetic")),
    elastic=st.booleans(),
)
@hypothesis.settings(max_examples=8, deadline=None)
def test_streaming_matches_trace_metrics_property(
    n, seed, num_steps, topology, elastic
):
    """Property acceptance bar: streaming-mode metrics equal trace-mode
    ``trace_metrics`` within float tolerance for EVERY registered policy ×
    the full 8-scenario library, under randomized fleet width, seed,
    horizon, workflow topology, and elastic capacity."""
    fleet = synthetic_fleet(n, seed=seed)
    rates = workload.synthetic_rates(n, seed=seed)
    scenarios = scenario_library(rates, num_steps=num_steps, seed=seed)
    workflow = {
        "none": None,
        "star": routing.coordinator_star(n),
        "chain": routing.pipeline_chain(n),
        "synthetic": routing.synthetic_workflow(n, seed=seed),
    }[topology]
    capacity = ELASTIC if elastic else None
    cfg = SimConfig()
    names = alloc.policy_names()
    arrivals = jnp.stack(
        [jnp.asarray(s.arrivals, jnp.float32) for s in scenarios]
    )
    for w, scen in enumerate(scenarios):
        vec, _, _, _ = simulate_stream_core(
            arrivals[w], fleet, cfg, names, workflow, capacity
        )
        for i, name in enumerate(names):
            tr = simulate(name, arrivals[w], fleet, cfg, workflow, capacity)
            want, _, _, _ = trace_metrics(
                tr, fleet.active, workflow, config=cfg
            )
            np.testing.assert_allclose(
                np.asarray(vec[i]), np.asarray(want), rtol=5e-3, atol=5e-3,
                err_msg=f"{name}/{scen.name}/{topology}/elastic={elastic}",
            )
