"""Streaming sweep kernel: parity with the trace-based oracle.

The streaming kernel (``simulator.simulate_stream_core`` +
``sweep._stream_grid_jit``) replaces the vmapped ``lax.switch`` (P² policy
evaluations per grid under the evaluate-all-branches lowering) with an
unrolled per-policy stack, and accumulates the METRIC_NAMES reductions in
the scan carry instead of materializing (S, N) traces.  The trace-based
path is kept as the parity oracle; these tests pin the acceptance
criterion: streaming metrics match it within float tolerance for every
registered policy on all four grid types, including under a workflow
topology and an elastic capacity config.

Tolerances are float32 accumulation-order noise: the streaming carry sums
sequentially where the trace path tree-reduces, and ``latency_std``
amplifies the difference through cancellation at the ~1000 s latency cap.
"""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import importlib

from repro.core import allocator as alloc
from repro.core import routing
from repro.core import workload
from repro.core.agents import PAPER_ARRIVAL_RATES, paper_fleet, synthetic_fleet
from repro.core.capacity import capacity_config
from repro.core.simulator import (
    METRIC_NAMES,
    SimConfig,
    simulate,
    simulate_stream_core,
    trace_metrics,
)
from repro.core.sweep import (
    scenario_library,
    sweep,
    sweep_capacity,
    sweep_fleets,
    sweep_workflows,
)

# The package re-exports the ``sweep`` *function* under the submodule's
# name, so reach the module itself through importlib.
sweep_mod = importlib.import_module("repro.core.sweep")

FLEET = paper_fleet()
RTOL, ATOL = 1e-3, 1e-3

ELASTIC = capacity_config(
    "reactive", cold_start_s=3.0, min_instances=1.0, name="reactive_cold"
)


def _assert_grids_match(streamed, traced, label):
    assert streamed.metrics.shape == traced.metrics.shape, label
    np.testing.assert_allclose(
        streamed.metrics, traced.metrics, rtol=RTOL, atol=ATOL, err_msg=label
    )
    np.testing.assert_allclose(
        streamed.per_agent_latency, traced.per_agent_latency,
        rtol=RTOL, atol=ATOL, err_msg=label,
    )
    np.testing.assert_allclose(
        streamed.per_agent_throughput, traced.per_agent_throughput,
        rtol=RTOL, atol=ATOL, err_msg=label,
    )
    np.testing.assert_allclose(
        streamed.per_agent_queue, traced.per_agent_queue,
        rtol=RTOL, atol=ATOL, err_msg=label,
    )


class TestStreamingIsDefault:
    def test_keep_traces_false_routes_to_streaming_kernel(self, monkeypatch):
        calls = []
        real = sweep_mod._stream_grid_jit

        def spy(*args, **kwargs):
            calls.append(1)
            return real(*args, **kwargs)

        monkeypatch.setattr(sweep_mod, "_stream_grid_jit", spy)
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=10, seed=0)
        sweep(FLEET, scen)
        assert calls, "keep_traces=False must default to the streaming kernel"

    def test_keep_traces_true_uses_trace_kernel(self, monkeypatch):
        monkeypatch.setattr(
            sweep_mod, "_stream_grid_jit",
            lambda *a, **k: pytest.fail("trace sweep hit the streaming kernel"),
        )
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=10, seed=0)
        res = sweep(FLEET, scen[:1], keep_traces=True)
        assert res.traces is not None

    def test_stream_with_keep_traces_rejected(self):
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=10, seed=0)
        with pytest.raises(ValueError, match="streaming"):
            sweep(FLEET, scen, keep_traces=True, stream=True)


class TestGridParity:
    """Acceptance: streaming matches the trace oracle on all four grids."""

    def test_sweep(self):
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=40, seed=0)
        _assert_grids_match(
            sweep(FLEET, scen), sweep(FLEET, scen, stream=False), "sweep"
        )

    def test_sweep_with_capacity(self):
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=40, seed=0)
        _assert_grids_match(
            sweep(FLEET, scen, capacity=ELASTIC),
            sweep(FLEET, scen, capacity=ELASTIC, stream=False),
            "sweep+capacity",
        )

    def test_sweep_fleets(self):
        fleets = [synthetic_fleet(n, seed=n) for n in (2, 3, 5)]
        _assert_grids_match(
            sweep_fleets(fleets, num_steps=25, seed=0),
            sweep_fleets(fleets, num_steps=25, seed=0, stream=False),
            "sweep_fleets",
        )

    def test_sweep_workflows(self):
        _assert_grids_match(
            sweep_workflows(FLEET, num_steps=25, seed=0),
            sweep_workflows(FLEET, num_steps=25, seed=0, stream=False),
            "sweep_workflows",
        )

    def test_sweep_capacity(self):
        _assert_grids_match(
            sweep_capacity(FLEET, num_steps=25, seed=0),
            sweep_capacity(FLEET, num_steps=25, seed=0, stream=False),
            "sweep_capacity",
        )

    def test_policy_subset(self):
        scen = scenario_library(PAPER_ARRIVAL_RATES, num_steps=20, seed=0)
        pols = ("water_filling", "round_robin")
        streamed = sweep(FLEET, scen, policies=pols)
        traced = sweep(FLEET, scen, policies=pols, stream=False)
        assert streamed.policy_names == pols
        _assert_grids_match(streamed, traced, "policy subset")


class TestStreamCoreAgainstSingleRuns:
    """Row i of the streaming stack must be policy names[i]'s own run —
    exactly one dispatch per registered policy, against ``simulate`` (the
    single-run ``lax.switch`` path, untouched by this kernel)."""

    ARR = workload.poisson(
        jnp.asarray(PAPER_ARRIVAL_RATES, jnp.float32), 50, jax.random.key(7)
    )

    @pytest.mark.parametrize(
        "workflow,capacity",
        [
            (None, None),
            (routing.coordinator_star(4), None),
            (None, ELASTIC),
            (routing.pipeline_chain(4), ELASTIC),
        ],
        ids=("plain", "workflow", "capacity", "workflow+capacity"),
    )
    def test_every_policy_row_matches_its_simulate(self, workflow, capacity):
        cfg = SimConfig()
        names = alloc.policy_names()
        vec, per_lat, per_tput, per_q = simulate_stream_core(
            self.ARR, FLEET, cfg, names, workflow, capacity
        )
        assert vec.shape == (len(names), len(METRIC_NAMES))
        for i, name in enumerate(names):
            tr = simulate(name, self.ARR, FLEET, cfg, workflow, capacity)
            want, want_lat, want_tput, want_q = trace_metrics(
                tr, FLEET.active, workflow, config=cfg
            )
            np.testing.assert_allclose(
                np.asarray(vec[i]), np.asarray(want),
                rtol=RTOL, atol=ATOL, err_msg=name,
            )
            np.testing.assert_allclose(
                np.asarray(per_lat[i]), np.asarray(want_lat),
                rtol=RTOL, atol=ATOL, err_msg=name,
            )
            np.testing.assert_allclose(
                np.asarray(per_tput[i]), np.asarray(want_tput),
                rtol=RTOL, atol=ATOL, err_msg=name,
            )
            np.testing.assert_allclose(
                np.asarray(per_q[i]), np.asarray(want_q),
                rtol=RTOL, atol=ATOL, err_msg=name,
            )


@hypothesis.given(
    n=st.integers(2, 4),
    seed=st.integers(0, 10),
    # Discrete horizons so examples share compiled scans instead of paying
    # one XLA compile per drawn shape.
    num_steps=st.sampled_from((12, 30)),
    topology=st.sampled_from(("none", "star", "chain", "synthetic")),
    elastic=st.booleans(),
)
@hypothesis.settings(max_examples=8, deadline=None)
def test_streaming_matches_trace_metrics_property(
    n, seed, num_steps, topology, elastic
):
    """Property acceptance bar: streaming-mode metrics equal trace-mode
    ``trace_metrics`` within float tolerance for EVERY registered policy ×
    the full 8-scenario library, under randomized fleet width, seed,
    horizon, workflow topology, and elastic capacity."""
    fleet = synthetic_fleet(n, seed=seed)
    rates = workload.synthetic_rates(n, seed=seed)
    scenarios = scenario_library(rates, num_steps=num_steps, seed=seed)
    workflow = {
        "none": None,
        "star": routing.coordinator_star(n),
        "chain": routing.pipeline_chain(n),
        "synthetic": routing.synthetic_workflow(n, seed=seed),
    }[topology]
    capacity = ELASTIC if elastic else None
    cfg = SimConfig()
    names = alloc.policy_names()
    arrivals = jnp.stack(
        [jnp.asarray(s.arrivals, jnp.float32) for s in scenarios]
    )
    for w, scen in enumerate(scenarios):
        vec, _, _, _ = simulate_stream_core(
            arrivals[w], fleet, cfg, names, workflow, capacity
        )
        for i, name in enumerate(names):
            tr = simulate(name, arrivals[w], fleet, cfg, workflow, capacity)
            want, _, _, _ = trace_metrics(
                tr, fleet.active, workflow, config=cfg
            )
            np.testing.assert_allclose(
                np.asarray(vec[i]), np.asarray(want), rtol=5e-3, atol=5e-3,
                err_msg=f"{name}/{scen.name}/{topology}/elastic={elastic}",
            )
