"""Fleet-as-a-pytree + fleet-axis sweep tests.

Covers the agent-count-scaling acceptance criteria: ``Fleet`` flows through
jit/vmap as a pytree, padded slots get exactly g = 0 from every registered
policy, a batched (fleet × policy × scenario) sweep over heterogeneous
fleet sizes matches the per-fleet unbatched ``sweep()`` within float
tolerance, and the device-sharded grid path is identical to the unsharded
one on a single device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import allocator as alloc
from repro.core import workload
from repro.core.agents import (
    Fleet,
    pad_fleet,
    paper_fleet,
    scale_fleet,
    stack_fleets,
    synthetic_fleet,
)
from repro.core.simulator import run_policy, simulate
from repro.core.sweep import (
    fleet_scenario_library,
    scenario_library,
    sweep,
    sweep_fleets,
)

FLEET_SIZES = (4, 8, 16, 64)
NUM_STEPS = 20
SEED = 0


def _fleets():
    return [
        scale_fleet(paper_fleet(), 4),
        synthetic_fleet(8, seed=8),
        synthetic_fleet(16, seed=16),
        synthetic_fleet(64, seed=64),
    ]


@pytest.fixture(scope="module")
def batched():
    """One batched sweep over all fleet sizes + the matching rate vectors."""
    fleets = _fleets()
    rates = [workload.synthetic_rates(f.num_agents, seed=SEED + i)
             for i, f in enumerate(fleets)]
    res = sweep_fleets(fleets, rates, num_steps=NUM_STEPS, seed=SEED)
    return fleets, rates, res


class TestFleetPytree:
    def test_flatten_roundtrip(self):
        fleet = paper_fleet()
        leaves, treedef = jax.tree_util.tree_flatten(fleet)
        assert len(leaves) == 5  # four profiles + the validity mask
        back = jax.tree_util.tree_unflatten(treedef, leaves)
        assert back.names == fleet.names
        np.testing.assert_array_equal(np.asarray(back.active), 1.0)

    def test_jit_passthrough(self):
        fleet = paper_fleet()
        total_min = jax.jit(lambda f: f.min_gpu.sum())(fleet)
        assert abs(float(total_min) - 1.0) < 1e-6

    def test_vmap_over_stacked_fleet(self):
        stacked = stack_fleets([synthetic_fleet(4, seed=1), synthetic_fleet(6, seed=2)])
        n_active = jax.vmap(lambda f: f.num_active)(stacked)
        np.testing.assert_allclose(np.asarray(n_active), [4.0, 6.0])

    def test_default_mask_is_all_ones(self):
        fleet = paper_fleet()
        np.testing.assert_array_equal(np.asarray(fleet.active), np.ones(4))
        assert float(fleet.num_active) == 4.0


class TestFleetGenerators:
    def test_synthetic_fleet_reproducible_and_valid(self):
        a, b = synthetic_fleet(12, seed=3), synthetic_fleet(12, seed=3)
        np.testing.assert_array_equal(np.asarray(a.min_gpu), np.asarray(b.min_gpu))
        a.validate()
        assert a.num_agents == 12
        assert float(a.min_gpu.sum()) < 1.0  # schedulable under G_total=1

    def test_scale_fleet_preserves_total_min_gpu(self):
        base = paper_fleet()
        # Non-multiples of the base size must preserve Σ min_gpu too.
        for n in (4, 5, 8, 13, 32, 100):
            big = scale_fleet(base, n)
            big.validate()
            assert big.num_agents == n
            np.testing.assert_allclose(
                float(big.min_gpu.sum()), float(base.min_gpu.sum()), rtol=1e-5
            )

    def test_pad_fleet_masks_padding(self):
        padded = pad_fleet(paper_fleet(), 10)
        padded.validate()
        assert padded.num_agents == 10
        assert float(padded.num_active) == 4.0
        np.testing.assert_array_equal(np.asarray(padded.active[4:]), 0.0)
        assert (np.asarray(padded.base_throughput) > 0).all()

    def test_stack_fleets_pads_to_widest(self):
        stacked = stack_fleets([synthetic_fleet(3, seed=0), synthetic_fleet(7, seed=1)])
        assert stacked.num_agents == 7
        assert np.asarray(stacked.min_gpu).shape == (2, 7)
        np.testing.assert_allclose(np.asarray(stacked.active).sum(axis=1), [3.0, 7.0])

    def test_pad_below_current_size_raises(self):
        with pytest.raises(ValueError):
            pad_fleet(paper_fleet(), 2)

    def test_scale_fleet_rejects_padded_input(self):
        with pytest.raises(ValueError, match="unpadded"):
            scale_fleet(pad_fleet(paper_fleet(), 8), 16)


class TestPaddedPolicies:
    """Padded slots must receive exactly g = 0 from every registered policy
    under randomized load, and the active slots must still respect the
    capacity invariants."""

    @pytest.mark.parametrize("policy", alloc.policy_names())
    def test_padding_gets_exactly_zero(self, policy):
        for seed in range(3):
            rng = np.random.default_rng(seed)
            n, n_pad = 5, 4
            fleet = pad_fleet(synthetic_fleet(n, seed=seed), n + n_pad)
            lam = jnp.asarray(
                np.concatenate([rng.uniform(0, 200, n), rng.uniform(0, 200, n_pad)]),
                jnp.float32,
            )  # even nonzero padded observations must be ignored
            q = jnp.asarray(
                np.concatenate([rng.uniform(0, 500, n), rng.uniform(0, 500, n_pad)]),
                jnp.float32,
            )
            g = np.asarray(
                alloc.dispatch(policy, jnp.asarray(int(rng.integers(0, 7))),
                               lam, lam, q, fleet, 1.0)
            )
            assert (g[n:] == 0.0).all(), (policy, seed, g[n:])
            assert (g >= -1e-6).all()
            assert g.sum() <= 1.0 + 1e-4

    @pytest.mark.parametrize("policy", alloc.policy_names())
    def test_padded_simulation_matches_unpadded(self, policy):
        fleet = paper_fleet()
        rates = jnp.asarray([80.0, 40.0, 45.0, 25.0], jnp.float32)
        arr = workload.constant(rates, 50)
        padded = pad_fleet(fleet, 16)
        arr_p = jnp.pad(arr, ((0, 0), (0, 12)))
        a = run_policy(policy, arr, fleet)
        b = run_policy(policy, arr_p, padded)
        g = np.asarray(simulate(policy, arr_p, padded).allocation)
        assert (g[:, 4:] == 0.0).all(), policy
        np.testing.assert_allclose(a.avg_latency, b.avg_latency, rtol=2e-3, atol=1e-2)
        np.testing.assert_allclose(a.latency_std, b.latency_std, rtol=2e-3, atol=1e-2)
        np.testing.assert_allclose(
            a.total_throughput, b.total_throughput, rtol=2e-3, atol=1e-2
        )

    def test_round_robin_exact_at_large_tick(self):
        """The active-rank rotation must be integer arithmetic: a float32
        mod would round ticks past 2^24 and skip/repeat agents."""
        fleet = pad_fleet(synthetic_fleet(3, seed=0), 8)
        zeros = jnp.zeros(8, jnp.float32)
        big = 2**24 + 1  # odd, unrepresentable in float32
        g = np.asarray(
            alloc.dispatch("round_robin", jnp.asarray(big), zeros, zeros, zeros,
                           fleet, 1.0)
        )
        assert int(g.argmax()) == big % 3
        assert g.sum() == 1.0

    def test_round_robin_cycles_active_slots_only(self):
        fleet = pad_fleet(synthetic_fleet(3, seed=0), 8)
        zeros = jnp.zeros(8, jnp.float32)
        hits = []
        for t in range(6):
            g = np.asarray(
                alloc.dispatch("round_robin", jnp.asarray(t), zeros, zeros, zeros,
                               fleet, 1.0)
            )
            assert g.sum() == 1.0
            hits.append(int(g.argmax()))
        assert hits == [0, 1, 2, 0, 1, 2]

    def test_static_equal_divides_by_active_count(self):
        fleet = pad_fleet(synthetic_fleet(5, seed=0), 12)
        zeros = jnp.zeros(12, jnp.float32)
        g = np.asarray(
            alloc.dispatch("static_equal", jnp.asarray(0), zeros, zeros, zeros, fleet, 1.0)
        )
        np.testing.assert_allclose(g[:5], 0.2, rtol=1e-6)
        assert (g[5:] == 0.0).all()


class TestFleetSweep:
    def test_grid_shape(self, batched):
        fleets, _, res = batched
        F, P, W = len(fleets), len(alloc.policy_names()), len(res.scenario_names)
        assert res.metrics.shape[:3] == (F, P, W)
        assert res.per_agent_latency.shape == (F, P, W, 64)
        assert np.isfinite(res.metrics).all()
        assert res.fleet_names == tuple(
            f"fleet{i}_n{f.num_agents}" for i, f in enumerate(fleets)
        )

    def test_batched_matches_unbatched_per_fleet(self, batched):
        """The acceptance criterion: every row of the padded/masked batched
        grid reproduces the unbatched per-fleet sweep within float tolerance."""
        fleets, rates, res = batched
        for i, fleet in enumerate(fleets):
            scen = scenario_library(rates[i], num_steps=NUM_STEPS, seed=SEED)
            unbatched = sweep(fleet, scen)
            np.testing.assert_allclose(
                res.metrics[i], unbatched.metrics, rtol=2e-3, atol=5e-2,
                err_msg=f"fleet {res.fleet_names[i]}",
            )
            n = fleet.num_agents
            np.testing.assert_allclose(
                res.per_agent_latency[i, :, :, :n], unbatched.per_agent_latency,
                rtol=2e-3, atol=5e-2,
            )
            # padded agents serve nothing
            assert (res.per_agent_throughput[i, :, :, n:] == 0.0).all()

    def test_sharded_matches_unsharded(self, batched):
        fleets, rates, res = batched
        plain = sweep_fleets(fleets, rates, num_steps=NUM_STEPS, seed=SEED, shard=False)
        np.testing.assert_array_equal(res.metrics, plain.metrics)
        np.testing.assert_array_equal(res.per_agent_latency, plain.per_agent_latency)

    def test_table_and_best_carry_fleet_axis(self, batched):
        fleets, _, res = batched
        table = res.table()
        assert table.columns[0] == "fleet"
        assert len(table.rows) == len(fleets) * len(res.policy_names) * len(res.scenario_names)
        best = table.best("avg_latency")
        assert set(best) == {
            f"{fl}/{sc}" for fl in res.fleet_names for sc in res.scenario_names
        }

    def test_summary_requires_fleet_on_batched_grid(self, batched):
        _, _, res = batched
        with pytest.raises(ValueError):
            res.summary("adaptive", "constant")
        s = res.summary("adaptive", "constant", fleet=res.fleet_names[0])
        assert np.isfinite(s.avg_latency)

    def test_mismatched_rate_vector_raises(self):
        fleets = [synthetic_fleet(4, seed=0), synthetic_fleet(8, seed=1)]
        rates = [workload.synthetic_rates(4, seed=0), workload.synthetic_rates(8, seed=1)]
        with pytest.raises(ValueError, match="rate vector"):
            sweep_fleets(fleets, rates[::-1], num_steps=5)  # swapped pair

    def test_fleet_scenario_library_matches_unbatched_generators(self):
        rates = [workload.synthetic_rates(4, seed=0), workload.synthetic_rates(6, seed=1)]
        names, arr = fleet_scenario_library(rates, n_max=6, num_steps=15, seed=3)
        assert arr.shape == (2, len(names), 15, 6)
        lib0 = scenario_library(rates[0], num_steps=15, seed=3)
        for w, s in enumerate(lib0):
            np.testing.assert_array_equal(
                np.asarray(arr[0, w, :, :4]), np.asarray(s.arrivals), err_msg=s.name
            )
        np.testing.assert_array_equal(np.asarray(arr[0, :, :, 4:]), 0.0)
